//! Blocked, multi-threaded GEMM kernels.
//!
//! Three entry points, matching the access patterns of the model and the
//! quantizers (all matrices row-major):
//!
//! * [`matmul`]        — C = A·B          (A: m×k, B: k×n)
//! * [`matmul_transb`] — C = A·Bᵀ         (A: m×k, B: n×k)  ← the hot one:
//!   `x · Ŵᵀ` with both operands iterating k contiguously (SIMD-friendly).
//! * [`matmul_at_b`]   — C = Aᵀ·B         (A: k×m, B: k×n)  — backprop.
//!
//! Parallelization: rows of C are chunked across the global thread pool;
//! each worker writes a disjoint row range, so no synchronization is needed
//! inside the kernel. The serial microkernel is written so LLVM
//! auto-vectorizes the inner loops (verified via the fig2 bench: ~8–20
//! GFLOP/s on the test machine).

use super::matrix::Matrix;
use crate::util::ThreadPool;

/// Threshold below which threading overhead is not worth it.
const PAR_FLOP_THRESHOLD: usize = 64 * 64 * 64;

struct SendPtr(*mut f32);
// SAFETY: a private `util::pool::SharedMut` twin — workers receive strictly
// disjoint row ranges of C (see `dispatch_rows`), and `parallel_for` joins
// them before the owning matrix is used again.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// C = A·B. Panics on shape mismatch.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let run = |lo: usize, hi: usize, c_data: &mut [f32]| {
        for i in lo..hi {
            let c_row = &mut c_data[(i - lo) * n..(i - lo + 1) * n];
            let a_row = a.row(i);
            // k-outer accumulation: C[i,:] += A[i,p] * B[p,:], unit-stride on
            // both the B row and the C row.
            for (p, &apv) in a_row.iter().enumerate().take(k) {
                if apv == 0.0 {
                    continue;
                }
                let b_row = b.row(p);
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += apv * bv;
                }
            }
        }
    };
    dispatch_rows(m, k * n, &mut c, run);
    c
}

/// C = A·Bᵀ (A: m×k, B: n×k). The serving-path pattern `x · Ŵᵀ`.
pub fn matmul_transb(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_transb_into(a, b, &mut c);
    c
}

/// [`matmul_transb`] writing into a caller-owned m×n output. Every element
/// of C is overwritten (no zeroing needed), so the serving loop can reuse
/// one activation buffer across decode ticks instead of allocating.
pub fn matmul_transb_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_transb: {}x{} @ ({}x{})ᵀ", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    assert_eq!(c.shape(), (m, n), "out shape {:?} vs ({m}, {n})", c.shape());
    let run = |lo: usize, hi: usize, c_data: &mut [f32]| {
        for i in lo..hi {
            let a_row = a.row(i);
            let c_row = &mut c_data[(i - lo) * n..(i - lo + 1) * n];
            for (j, cv) in c_row.iter_mut().enumerate() {
                let b_row = b.row(j);
                // contiguous dot product — auto-vectorized
                let mut acc0 = 0.0f32;
                let mut acc1 = 0.0f32;
                let mut acc2 = 0.0f32;
                let mut acc3 = 0.0f32;
                let chunks = k / 4;
                for c4 in 0..chunks {
                    let p = c4 * 4;
                    acc0 += a_row[p] * b_row[p];
                    acc1 += a_row[p + 1] * b_row[p + 1];
                    acc2 += a_row[p + 2] * b_row[p + 2];
                    acc3 += a_row[p + 3] * b_row[p + 3];
                }
                let mut acc = acc0 + acc1 + acc2 + acc3;
                for p in chunks * 4..k {
                    acc += a_row[p] * b_row[p];
                }
                *cv = acc;
            }
        }
    };
    dispatch_rows(m, k * n, c, run);
}

/// C = Aᵀ·B (A: k×m, B: k×n) — the dW = xᵀ·g backprop pattern.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_at_b: ({}x{})ᵀ @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let run = |lo: usize, hi: usize, c_data: &mut [f32]| {
        for p in 0..k {
            let a_row = a.row(p);
            let b_row = b.row(p);
            for i in lo..hi {
                let av = a_row[i];
                if av == 0.0 {
                    continue;
                }
                let c_row = &mut c_data[(i - lo) * n..(i - lo + 1) * n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += av * bv;
                }
            }
        }
    };
    dispatch_rows(m, k * n, &mut c, run);
    c
}

/// Split output rows across the pool; each worker fills a disjoint slice of C.
fn dispatch_rows<F>(m: usize, flops_per_row: usize, c: &mut Matrix, run: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let n = c.cols;
    if m * flops_per_row < PAR_FLOP_THRESHOLD || m == 1 {
        let mut tmp = std::mem::take(&mut c.data);
        run(0, m, &mut tmp);
        c.data = tmp;
        return;
    }
    let ptr = SendPtr(c.data.as_mut_ptr());
    let ptr_ref = &ptr;
    ThreadPool::global().parallel_for(m, move |lo, hi| {
        // SAFETY: chunks partition [0, m), so rows [lo, hi) of C — and the
        // carved slice — belong to exactly one worker; C's buffer outlives
        // the join in `parallel_for`.
        let slice = unsafe { std::slice::from_raw_parts_mut(ptr_ref.0.add(lo * n), (hi - lo) * n) };
        run(lo, hi, slice);
    });
}

/// y = A·x for a vector x (len = A.cols).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    (0..a.rows)
        .map(|i| a.row(i).iter().zip(x).map(|(&w, &v)| w * v).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, prop_check};
    use crate::util::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for p in 0..a.cols {
                    acc += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn variants_agree_with_naive() {
        prop_check(24, |g| {
            let m = g.usize(1..=33);
            let k = g.usize(1..=40);
            let n = g.usize(1..=29);
            let mut rng = g.rng().fork(1);
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let want = naive_matmul(&a, &b);
            assert_allclose(&matmul(&a, &b).data, &want.data, 1e-4, 1e-4, "matmul");
            assert_allclose(
                &matmul_transb(&a, &b.transpose()).data,
                &want.data,
                1e-4,
                1e-4,
                "matmul_transb",
            );
            assert_allclose(
                &matmul_at_b(&a.transpose(), &b).data,
                &want.data,
                1e-4,
                1e-4,
                "matmul_at_b",
            );
            Ok(())
        });
    }

    #[test]
    fn large_parallel_path_matches_serial() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(130, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 120, 1.0, &mut rng);
        let par = matmul(&a, &b);
        let naive = naive_matmul(&a, &b);
        assert_allclose(&par.data, &naive.data, 1e-4, 1e-4, "parallel gemm");
    }

    #[test]
    fn matmul_transb_into_overwrites_dirty_buffer() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(5, 12, 1.0, &mut rng);
        let b = Matrix::randn(9, 12, 1.0, &mut rng);
        let mut dirty = Matrix::from_fn(5, 9, |i, j| (i * 31 + j) as f32);
        matmul_transb_into(&a, &b, &mut dirty);
        assert_eq!(dirty.data, matmul_transb(&a, &b).data);
    }

    #[test]
    fn matvec_matches() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(17, 23, 1.0, &mut rng);
        let x: Vec<f32> = (0..23).map(|i| i as f32 * 0.1).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(23, 1, x);
        let want = matmul(&a, &xm);
        assert_allclose(&y, &want.data, 1e-5, 1e-5, "matvec");
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(9, 9, 1.0, &mut rng);
        let i = Matrix::eye(9);
        assert_allclose(&matmul(&a, &i).data, &a.data, 1e-6, 1e-6, "A·I");
        assert_allclose(&matmul(&i, &a).data, &a.data, 1e-6, 1e-6, "I·A");
    }
}
