//! Dense f32 tensor substrate: a row-major [`Matrix`] plus the blocked,
//! multi-threaded GEMM kernels the quantizers / model / serving path run on.

pub mod gemm;
pub mod matrix;

pub use gemm::{matmul, matmul_at_b, matmul_transb, matmul_transb_into};
pub use matrix::Matrix;
