//! Flight recorder: a bounded ring buffer of per-request lifecycle
//! events, kept cheap enough to leave on in production and dumped for
//! postmortems — on demand, or automatically when the recorder detects
//! an anomaly.
//!
//! The recorder is owned by the serving loop and written from that one
//! thread, so it needs no synchronization; events are timestamped on the
//! shared observability clock ([`super::trace::now_ns`]) so a dump lines
//! up with a Chrome trace of the same run.
//!
//! Event schema (one entry per state transition of a request):
//!
//! | kind | payload | meaning |
//! |---|---|---|
//! | `submitted` | — | request entered the admission queue |
//! | `rejected` | `reason` | refused (queue full, over KV budget, ...) |
//! | `admitted` | `prefix_hit_tokens`, `reserved_tokens` | granted KV (worst-case token reservation), prefill started |
//! | `prefill_chunk` | `tokens` | one chunk of the prompt processed |
//! | `first_token` | — | TTFT point |
//! | `done` | `generated` | completed normally |
//! | `cancelled` | — | cancelled by the client |
//! | `released` | — | KV blocks and adapter pin returned |
//! | `failed` | `reason`, `retryable` | failed in flight (engine error, deadline, quarantine, drain) |
//! | `quarantined` | — | non-finite logits detected; terminal (paired with an anomaly trip) |
//! | `retried` | — | retry-by-re-prefill re-entered the admission queue |
//!
//! Anomaly tripwires (all dump the ring into [`FlightRecorder::take_anomaly`]
//! and log a warning, then re-arm):
//!
//! * **Rejection storm** — ≥ [`STORM_REJECTIONS`] rejections inside a
//!   one-second window, the signature of an admission-control death
//!   spiral.
//! * **Stall** — [`STALL_TICKS`] consecutive server steps with work in
//!   flight but no progress event (no chunk, token, completion, or
//!   admission), the livelock-adjacent shape.
//! * **External trips** — owners can arm the same dump path for signals
//!   the recorder can't see itself via [`FlightRecorder::trip_anomaly`]
//!   (the server uses this for KV seal-error threshold breaches).
//!
//! The storm/stall thresholds default to the constants above and are
//! per-instance tunable ([`FlightRecorder::configure`]) — `ServeCfg`
//! exposes them as `storm_rejections`/`storm_window_ms`/`stall_ticks`.

use super::json::Json;
use super::trace::now_ns;
use std::collections::VecDeque;

/// Default rejections within the storm window that count as a storm.
pub const STORM_REJECTIONS: usize = 8;
/// Default storm window.
pub const STORM_WINDOW_NS: u64 = 1_000_000_000;
/// Default consecutive busy-but-progress-free steps that count as a stall.
pub const STALL_TICKS: usize = 512;

const DEFAULT_CAP: usize = 4096;

#[derive(Clone, Debug, PartialEq)]
pub enum FlightKind {
    Submitted,
    Rejected { reason: &'static str },
    Admitted { prefix_hit_tokens: usize, reserved_tokens: usize },
    PrefillChunk { tokens: usize },
    FirstToken,
    Done { generated: usize },
    Cancelled,
    Released,
    /// The sequence failed in flight. `reason` is the stable key shared
    /// with the `lords_failed_total` label; `retryable` means a
    /// retry-by-re-prefill was scheduled.
    Failed { reason: &'static str, retryable: bool },
    /// The sequence was quarantined (non-finite logits) — terminal, and
    /// always paired with an anomaly trip.
    Quarantined,
    /// A failed sequence re-entered the admission queue after its retry
    /// backoff.
    Retried,
}

#[derive(Clone, Debug)]
pub struct FlightEvent {
    pub t_ns: u64,
    /// Request id (the server's session id).
    pub seq: u64,
    pub kind: FlightKind,
}

#[derive(Debug)]
pub struct FlightRecorder {
    ring: VecDeque<FlightEvent>,
    cap: usize,
    /// Events displaced from the ring since creation.
    evicted: u64,
    /// Timestamps of recent rejections (storm window).
    reject_times: VecDeque<u64>,
    /// Consecutive busy steps without a progress event.
    stall_streak: usize,
    progressed_since_tick: bool,
    last_anomaly: Option<Anomaly>,
    /// Storm threshold (see [`STORM_REJECTIONS`]); 0 disables the tripwire.
    storm_rejections: usize,
    storm_window_ns: u64,
    /// Stall threshold (see [`STALL_TICKS`]); 0 disables the tripwire.
    stall_ticks: usize,
}

/// An automatic dump: why it fired plus the ring contents at that moment.
#[derive(Clone, Debug)]
pub struct Anomaly {
    pub reason: String,
    pub dump: String,
}

impl Default for FlightRecorder {
    fn default() -> FlightRecorder {
        FlightRecorder::new(DEFAULT_CAP)
    }
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            ring: VecDeque::with_capacity(cap.min(DEFAULT_CAP)),
            cap: cap.max(1),
            evicted: 0,
            reject_times: VecDeque::new(),
            stall_streak: 0,
            progressed_since_tick: false,
            last_anomaly: None,
            storm_rejections: STORM_REJECTIONS,
            storm_window_ns: STORM_WINDOW_NS,
            stall_ticks: STALL_TICKS,
        }
    }

    /// Tune the tripwire thresholds (a threshold of 0 disables that
    /// tripwire). The server feeds these from `ServeCfg`.
    pub fn configure(&mut self, storm_rejections: usize, storm_window_ns: u64, stall_ticks: usize) {
        self.storm_rejections = storm_rejections;
        self.storm_window_ns = storm_window_ns.max(1);
        self.stall_ticks = stall_ticks;
    }

    /// Append one lifecycle event (oldest event falls off past capacity).
    pub fn push(&mut self, seq: u64, kind: FlightKind) {
        let progress = !matches!(kind, FlightKind::Submitted | FlightKind::Rejected { .. });
        if progress {
            self.progressed_since_tick = true;
        }
        let t_ns = now_ns();
        if let FlightKind::Rejected { .. } = kind {
            self.note_rejection(t_ns);
        }
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.evicted += 1;
        }
        self.ring.push_back(FlightEvent { t_ns, seq, kind });
    }

    fn note_rejection(&mut self, t_ns: u64) {
        self.reject_times.push_back(t_ns);
        let window_ns = self.storm_window_ns;
        while self.reject_times.front().is_some_and(|&t| t + window_ns < t_ns) {
            self.reject_times.pop_front();
        }
        if self.storm_rejections > 0 && self.reject_times.len() >= self.storm_rejections {
            let n = self.reject_times.len();
            let ms = window_ns / 1_000_000;
            self.trip(format!("rejection storm: {n} rejections within {ms}ms"));
            self.reject_times.clear();
        }
    }

    /// Called once per server step. `busy` means work was in flight
    /// (queued, prefilling, or running); progress is tracked from the
    /// events pushed since the previous call.
    pub fn note_tick(&mut self, busy: bool) {
        if !busy || self.progressed_since_tick {
            self.stall_streak = 0;
        } else {
            self.stall_streak += 1;
            if self.stall_ticks > 0 && self.stall_streak >= self.stall_ticks {
                let n = self.stall_streak;
                self.trip(format!("stall: {n} consecutive busy steps without progress"));
                self.stall_streak = 0;
            }
        }
        self.progressed_since_tick = false;
    }

    /// Arm the anomaly dump for a condition the recorder can't observe
    /// itself (e.g. the server's KV seal-error threshold breaches).
    pub fn trip_anomaly(&mut self, reason: String) {
        self.trip(reason);
    }

    fn trip(&mut self, reason: String) {
        crate::warn_log!("flight-recorder anomaly: {reason}");
        self.last_anomaly = Some(Anomaly { reason, dump: self.dump() });
    }

    /// The most recent automatic dump, if a tripwire fired (clears it).
    pub fn take_anomaly(&mut self) -> Option<Anomaly> {
        self.last_anomaly.take()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn events(&self) -> impl Iterator<Item = &FlightEvent> {
        self.ring.iter()
    }

    /// Serialize the ring as a JSON document (oldest event first).
    pub fn dump(&self) -> String {
        let events: Vec<Json> = self
            .ring
            .iter()
            .map(|e| {
                let mut kv = vec![
                    ("t_ns".into(), Json::Num(e.t_ns as f64)),
                    ("seq".into(), Json::Num(e.seq as f64)),
                    ("kind".into(), Json::Str(kind_name(&e.kind).into())),
                ];
                match &e.kind {
                    FlightKind::Rejected { reason } => {
                        kv.push(("reason".into(), Json::Str(reason.to_string())));
                    }
                    FlightKind::Admitted { prefix_hit_tokens, reserved_tokens } => {
                        kv.push((
                            "prefix_hit_tokens".into(),
                            Json::Num(*prefix_hit_tokens as f64),
                        ));
                        kv.push(("reserved_tokens".into(), Json::Num(*reserved_tokens as f64)));
                    }
                    FlightKind::PrefillChunk { tokens } => {
                        kv.push(("tokens".into(), Json::Num(*tokens as f64)));
                    }
                    FlightKind::Done { generated } => {
                        kv.push(("generated".into(), Json::Num(*generated as f64)));
                    }
                    FlightKind::Failed { reason, retryable } => {
                        kv.push(("reason".into(), Json::Str(reason.to_string())));
                        kv.push(("retryable".into(), Json::Bool(*retryable)));
                    }
                    _ => {}
                }
                Json::Obj(kv)
            })
            .collect();
        Json::Obj(vec![
            ("events".into(), Json::Arr(events)),
            ("evicted".into(), Json::Num(self.evicted as f64)),
        ])
        .render()
    }
}

fn kind_name(k: &FlightKind) -> &'static str {
    match k {
        FlightKind::Submitted => "submitted",
        FlightKind::Rejected { .. } => "rejected",
        FlightKind::Admitted { .. } => "admitted",
        FlightKind::PrefillChunk { .. } => "prefill_chunk",
        FlightKind::FirstToken => "first_token",
        FlightKind::Done { .. } => "done",
        FlightKind::Cancelled => "cancelled",
        FlightKind::Released => "released",
        FlightKind::Failed { .. } => "failed",
        FlightKind::Quarantined => "quarantined",
        FlightKind::Retried => "retried",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_dump_parses() {
        let mut fr = FlightRecorder::new(4);
        for seq in 0..6 {
            fr.push(seq, FlightKind::Submitted);
        }
        assert_eq!(fr.len(), 4);
        let doc = Json::parse(&fr.dump()).unwrap();
        let events = doc.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        // oldest two fell off
        assert_eq!(events[0].get("seq").unwrap().as_num(), Some(2.0));
        assert_eq!(doc.get("evicted").unwrap().as_num(), Some(2.0));
    }

    #[test]
    fn rejection_storm_trips() {
        let mut fr = FlightRecorder::default();
        for seq in 0..STORM_REJECTIONS as u64 {
            fr.push(seq, FlightKind::Rejected { reason: "queue_full" });
        }
        let anomaly = fr.take_anomaly().expect("storm should trip");
        assert!(anomaly.reason.contains("rejection storm"));
        assert!(Json::parse(&anomaly.dump).is_ok());
        // tripwire re-arms: no anomaly pending afterwards
        assert!(fr.take_anomaly().is_none());
    }

    #[test]
    fn stall_trips_only_when_busy_without_progress() {
        let mut fr = FlightRecorder::default();
        for _ in 0..STALL_TICKS {
            fr.note_tick(false); // idle: never a stall
        }
        assert!(fr.take_anomaly().is_none());
        for _ in 0..STALL_TICKS {
            fr.push(1, FlightKind::PrefillChunk { tokens: 8 });
            fr.note_tick(true); // busy but progressing
        }
        assert!(fr.take_anomaly().is_none());
        for _ in 0..STALL_TICKS {
            fr.note_tick(true); // busy, no progress
        }
        let anomaly = fr.take_anomaly().expect("stall should trip");
        assert!(anomaly.reason.contains("stall"));
    }

    #[test]
    fn configured_thresholds_override_defaults() {
        let mut fr = FlightRecorder::default();
        fr.configure(3, STORM_WINDOW_NS, 4);
        for seq in 0..3 {
            fr.push(seq, FlightKind::Rejected { reason: "queue_full" });
        }
        assert!(fr.take_anomaly().expect("lowered storm threshold trips").reason.contains("storm"));
        for _ in 0..4 {
            fr.note_tick(true);
        }
        assert!(fr.take_anomaly().expect("lowered stall threshold trips").reason.contains("stall"));
        // 0 disables a tripwire entirely.
        fr.configure(0, STORM_WINDOW_NS, 0);
        for seq in 0..64 {
            fr.push(seq, FlightKind::Rejected { reason: "queue_full" });
            fr.note_tick(true);
        }
        assert!(fr.take_anomaly().is_none());
    }

    #[test]
    fn external_trip_dumps_the_ring() {
        let mut fr = FlightRecorder::default();
        fr.push(9, FlightKind::FirstToken);
        fr.trip_anomaly("kv seal error above threshold".to_string());
        let anomaly = fr.take_anomaly().expect("external trip arms the dump");
        assert!(anomaly.reason.contains("seal error"));
        let doc = Json::parse(&anomaly.dump).unwrap();
        assert_eq!(doc.get("events").unwrap().as_arr().unwrap().len(), 1);
    }
}
