//! Quantization-quality telemetry — the numeric-fidelity pillar of the
//! observability stack.
//!
//! The flight recorder and span tracing see *time*; this module sees
//! *numbers*: how far the quantized weights, the packed KV tiles, and the
//! end-to-end logits sit from their exact references, on live traffic.
//! Three signal families, all observe-only (none of them may perturb the
//! served token streams — `tests/obs.rs` enforces this bitwise):
//!
//! - **Weight error** (once at engine build and at adapter registration):
//!   relative Frobenius error between a reference weight and its
//!   quantized reconstruction, exported per layer/linear-slot/tenant.
//!   The registry's gauges are integers, so the value is stored in
//!   parts-per-million ([`ppm`]).
//! - **KV seal error** (steady state, near-free): the moment a staging
//!   tail seals into a packed tile is the one place the dense rows and
//!   the packed codes are both in hand — one dequant pass over the
//!   just-packed tile yields the true round-trip error of that block
//!   without touching the serving read path. [`KvSealObs`], installed
//!   into the pool by `NativeEngine::install_quality`, records one
//!   histogram sample per sealed tile; a tile whose relative error
//!   exceeds a configurable threshold bumps a breach counter that the
//!   server turns into a flight-recorder anomaly dump.
//! - **Logit-drift sentinel** (deterministic cadence, default off): the
//!   server re-runs one sequence's decode step through the reference
//!   path on a shadow KV sequence and records top-1 agreement plus
//!   max-abs logit drift. The served token always comes from the batched
//!   path — see `NativeEngine::sentinel_probe` for the non-perturbation
//!   argument.
//!
//! These are exactly the signals the blocked ROADMAP directions need:
//! per-layer error for mixed-precision bit allocation, seal error +
//! block heat for runtime precision demotion, and the sentinel as the
//! guardrail for zero-downtime scale refinement.

use crate::adapters::AdapterFactors;
use crate::kvquant::scales::PackedTile;
use crate::model::{LinearWeight, Model};
use crate::obs::json::Json;
use crate::obs::metrics::{Counter, Gauge, Histogram, Labels, Registry};
use crate::quant::error::quant_error_rel_frob;
use crate::tensor::Matrix;

/// Per-layer weight reconstruction error of the base model, in ppm.
pub const WEIGHT_ERR_FAMILY: &str = "lords_weight_quant_rel_error_ppm";
/// Per-layer effective-weight delta introduced by a tenant adapter, in ppm.
pub const ADAPTER_ERR_FAMILY: &str = "lords_adapter_weight_rel_error_ppm";
/// Relative Frobenius round-trip error of sealed KV tiles, per kv tier.
pub const SEAL_ERR_FAMILY: &str = "lords_kv_seal_rel_error";
/// Sealed tiles whose relative error exceeded the configured threshold.
pub const SEAL_BREACH_FAMILY: &str = "lords_kv_seal_err_breaches_total";
/// Sentinel top-1 agreement samples (1 = batched and reference agree).
pub const SENTINEL_AGREE_FAMILY: &str = "lords_sentinel_top1_agree";
/// Sentinel max-abs logit drift between batched and reference paths.
pub const SENTINEL_DRIFT_FAMILY: &str = "lords_sentinel_logit_drift";
/// Sentinel probes that ran to completion.
pub const SENTINEL_PROBES_FAMILY: &str = "lords_sentinel_probes_total";
/// Sentinel probes skipped (pool full, sequence released mid-probe, …).
pub const SENTINEL_SKIPPED_FAMILY: &str = "lords_sentinel_skipped_total";
/// Ticks since each live KV block was last read, sampled every tick.
pub const COLDNESS_FAMILY: &str = "lords_kv_block_coldness_ticks";

/// Log-spaced bounds for relative-error histograms (dimensionless).
pub const REL_ERR_BOUNDS: &[f64] =
    &[0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0];

/// Log-spaced bounds for the sentinel's max-abs logit drift.
pub const DRIFT_BOUNDS: &[f64] = &[1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];

/// Bounds for block coldness in ticks (a block read during the last tick
/// has coldness 1).
pub const COLDNESS_BOUNDS: &[f64] =
    &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];

/// The families `/quality` exposes (everything this module owns).
const QUALITY_FAMILIES: &[&str] = &[
    WEIGHT_ERR_FAMILY,
    ADAPTER_ERR_FAMILY,
    SEAL_ERR_FAMILY,
    SEAL_BREACH_FAMILY,
    SENTINEL_AGREE_FAMILY,
    SENTINEL_DRIFT_FAMILY,
    SENTINEL_PROBES_FAMILY,
    SENTINEL_SKIPPED_FAMILY,
    COLDNESS_FAMILY,
];

const WEIGHT_ERR_HELP: &str =
    "Relative Frobenius weight reconstruction error, parts-per-million.";
const ADAPTER_ERR_HELP: &str =
    "Adapter-induced effective-weight delta over the base, parts-per-million.";

/// Relative error as an integer gauge value: parts-per-million, rounded.
pub fn ppm(rel: f32) -> i64 {
    (f64::from(rel) * 1e6).round() as i64
}

fn weight_err_gauge(
    reg: &Registry,
    family: &str,
    help: &str,
    layer: usize,
    linear: &str,
    tenant: &str,
) -> Gauge {
    let layer = layer.to_string();
    // METRIC-OK: `family` is one of the WEIGHT/ADAPTER_ERR_FAMILY consts,
    // forwarded by the registration helpers below; both rows are in the
    // README metrics table.
    reg.gauge_with_help(
        family,
        &[("layer", layer.as_str()), ("linear", linear), ("tenant", tenant)],
        help,
    )
}

/// Seal-time KV quality sink, installed into a [`crate::kvquant::KvPool`].
///
/// Holds only atomic metric handles, so the pool can record from the
/// `&self` seal path. `threshold <= 0` disables breach counting (the
/// histogram always records).
#[derive(Debug)]
pub struct KvSealObs {
    hist: Histogram,
    breaches: Counter,
    threshold: f64,
}

impl KvSealObs {
    /// Register the seal-error histogram for one kv tier (`"int8"`,
    /// `"int4"`) plus the shared breach counter.
    pub fn new(reg: &Registry, tier: &str, threshold: f64) -> KvSealObs {
        let hist = reg.histogram_with_help(
            SEAL_ERR_FAMILY,
            &[("kv", tier)],
            REL_ERR_BOUNDS,
            "Relative Frobenius round-trip error of each sealed KV tile, by kv-bits tier.",
        );
        let breaches = reg.counter_with_help(
            SEAL_BREACH_FAMILY,
            &[],
            "Sealed KV tiles whose relative error exceeded the configured threshold.",
        );
        KvSealObs { hist, breaches, threshold }
    }

    /// Record the round-trip error of one freshly sealed tile. `dense` is
    /// the staging tail the tile was packed from; `lut` is the codebook's
    /// level table. One dequant pass over `packed` — the only extra work
    /// quality telemetry adds to the steady-state serving path.
    pub fn record(&self, dense: &Matrix, packed: &PackedTile, lut: &[f32]) {
        let mut crow = vec![0u8; dense.cols];
        let mut out = vec![0.0f32; dense.cols];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..dense.rows {
            packed.dequant_row_into(i, lut, &mut crow, &mut out);
            for (&w, &w_hat) in dense.row(i).iter().zip(out.iter()) {
                let d = f64::from(w) - f64::from(w_hat);
                num += d * d;
                den += f64::from(w) * f64::from(w);
            }
        }
        let rel = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
        self.hist.observe(rel);
        if self.threshold > 0.0 && rel > self.threshold {
            self.breaches.inc();
        }
    }
}

/// Record per-layer weight reconstruction error of `quantized` against a
/// dense `reference` (the pre-quantization model the CLI and examples
/// keep around), as `lords_weight_quant_rel_error_ppm{layer,linear,tenant}`
/// gauges. Call once after engine build — this materializes every
/// effective weight and is not a steady-state path.
pub fn record_weight_errors(reg: &Registry, tenant: &str, reference: &Model, quantized: &Model) {
    for (li, (rl, ql)) in reference.layers.iter().zip(quantized.layers.iter()).enumerate() {
        for ((name, rw), (_, qw)) in rl.linears().iter().zip(ql.linears().iter()) {
            let rel = quant_error_rel_frob(&rw.effective(), &qw.effective());
            weight_err_gauge(reg, WEIGHT_ERR_FAMILY, WEIGHT_ERR_HELP, li, name, tenant)
                .set(ppm(rel));
        }
    }
}

/// Record what `model` can self-report without an external reference:
/// dense slots are exactly representable (0 ppm) and QAT LoRDS slots
/// carry their own shadow weight. Frozen-code slots are skipped — their
/// true error needs the dense reference, via [`record_weight_errors`].
pub fn record_self_weight_errors(reg: &Registry, model: &Model) {
    for (li, lw) in model.layers.iter().enumerate() {
        for (name, w) in lw.linears() {
            let rel = match w {
                LinearWeight::Dense(_) => 0.0,
                LinearWeight::Lords { shadow_w: Some(shadow), .. } => {
                    quant_error_rel_frob(shadow, &w.effective())
                }
                _ => continue,
            };
            weight_err_gauge(reg, WEIGHT_ERR_FAMILY, WEIGHT_ERR_HELP, li, name, "base")
                .set(ppm(rel));
        }
    }
}

/// Record the effective-weight delta a tenant's adapter introduces over
/// the shared frozen codes: `‖W(B',A') − W(B,A)‖_F / ‖W(B,A)‖_F` per
/// adapted linear, as `lords_adapter_weight_rel_error_ppm` gauges. Call
/// at adapter registration (materializes two dense weights per slot).
pub fn record_adapter_weight_errors(
    reg: &Registry,
    tenant: &str,
    model: &Model,
    factors: &AdapterFactors,
) {
    for (li, (lw, lf)) in model.layers.iter().zip(factors.layers.iter()).enumerate() {
        for (si, (name, w)) in lw.linears().iter().enumerate() {
            let (LinearWeight::Lords { q, .. }, Some(pair)) = (w, &lf.linears[si]) else {
                continue;
            };
            let base = q.dequantize();
            let adapted = q.dequantize_with(&pair.b, &pair.a);
            weight_err_gauge(reg, ADAPTER_ERR_FAMILY, ADAPTER_ERR_HELP, li, name, tenant)
                .set(ppm(quant_error_rel_frob(&base, &adapted)));
        }
    }
}

/// The `/quality` admin payload: every quality family in the registry,
/// rendered from a live snapshot (no serving-thread cooperation needed).
pub fn quality_json(reg: &Registry) -> Json {
    let snap = reg.snapshot();
    let keep = |name: &str| QUALITY_FAMILIES.contains(&name);
    let labels_json = |labels: &Labels| {
        Json::Obj(labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect())
    };
    let counters = snap
        .counters
        .iter()
        .filter(|c| keep(&c.name))
        .map(|c| {
            Json::Obj(vec![
                ("name".to_string(), Json::Str(c.name.clone())),
                ("labels".to_string(), labels_json(&c.labels)),
                ("value".to_string(), Json::Num(c.value as f64)),
            ])
        })
        .collect();
    let gauges = snap
        .gauges
        .iter()
        .filter(|g| keep(&g.name))
        .map(|g| {
            Json::Obj(vec![
                ("name".to_string(), Json::Str(g.name.clone())),
                ("labels".to_string(), labels_json(&g.labels)),
                ("value".to_string(), Json::Num(g.value as f64)),
            ])
        })
        .collect();
    let histograms = snap
        .histograms
        .iter()
        .filter(|h| keep(&h.name))
        .map(|h| {
            Json::Obj(vec![
                ("name".to_string(), Json::Str(h.name.clone())),
                ("labels".to_string(), labels_json(&h.labels)),
                ("bounds".to_string(), Json::Arr(h.bounds.iter().map(|&b| Json::Num(b)).collect())),
                (
                    "buckets".to_string(),
                    Json::Arr(h.buckets.iter().map(|&c| Json::Num(c as f64)).collect()),
                ),
                ("sum".to_string(), Json::Num(h.sum)),
                ("count".to_string(), Json::Num(h.count as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("counters".to_string(), Json::Arr(counters)),
        ("gauges".to_string(), Json::Arr(gauges)),
        ("histograms".to_string(), Json::Arr(histograms)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Codebook;
    use crate::util::Rng;

    #[test]
    fn seal_obs_records_round_trip_error_and_breaches() {
        let reg = Registry::new();
        let obs = KvSealObs::new(&reg, "int4", 1e-9);
        let cb = Codebook::normal_float(4);
        let mut rng = Rng::new(7);
        let x = Matrix::randn(8, 16, 0.5, &mut rng);
        let tile = PackedTile::quantize(&x, 2, &cb);
        obs.record(&x, &tile, &cb.levels);
        let snap = reg.snapshot();
        let h = &snap.histograms[0];
        assert_eq!(h.name, SEAL_ERR_FAMILY);
        assert_eq!(h.count, 1);
        assert!(h.sum > 0.0 && h.sum < 1.0, "4-bit rel error should be small: {}", h.sum);
        // Threshold of 1e-9 means any real error counts as a breach.
        assert_eq!(snap.counters.iter().find(|c| c.name == SEAL_BREACH_FAMILY).unwrap().value, 1);
    }

    #[test]
    fn zero_tile_records_zero_error() {
        let reg = Registry::new();
        let obs = KvSealObs::new(&reg, "int8", 0.25);
        let cb = Codebook::normal_float(8);
        let x = Matrix::zeros(4, 8);
        let tile = PackedTile::quantize(&x, 1, &cb);
        obs.record(&x, &tile, &cb.levels);
        let snap = reg.snapshot();
        assert_eq!(snap.histograms[0].sum, 0.0);
        assert_eq!(snap.counters.iter().find(|c| c.name == SEAL_BREACH_FAMILY).unwrap().value, 0);
    }

    #[test]
    fn quality_json_filters_to_quality_families_only() {
        let reg = Registry::new();
        reg.counter("lords_requests_total", &[]).inc();
        weight_err_gauge(&reg, WEIGHT_ERR_FAMILY, WEIGHT_ERR_HELP, 0, "wq", "base").set(1234);
        reg.histogram(SEAL_ERR_FAMILY, &[("kv", "int4")], REL_ERR_BOUNDS).observe(0.05);
        let j = quality_json(&reg);
        let rendered = j.render();
        assert!(rendered.contains(WEIGHT_ERR_FAMILY));
        assert!(rendered.contains(SEAL_ERR_FAMILY));
        assert!(!rendered.contains("lords_requests_total"));
        // Round-trips through the parser.
        let back = Json::parse(&rendered).expect("quality JSON parses");
        assert_eq!(back.get("gauges").and_then(Json::as_arr).map(|a| a.len()), Some(1));
    }

    #[test]
    fn ppm_rounds_sanely() {
        assert_eq!(ppm(0.0), 0);
        assert_eq!(ppm(0.05), 50_000);
        assert_eq!(ppm(1.0), 1_000_000);
    }
}
