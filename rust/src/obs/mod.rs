//! # Observability: metrics, spans, flight recorder, quality, admin HTTP
//!
//! Zero-dependency instrumentation for the serving stack, built so that
//! *off is near-free* (one relaxed atomic load per would-be span; metric
//! handles are plain atomics with no branches) and *on does not perturb
//! results* (served token streams are bitwise identical with tracing —
//! and quality telemetry — enabled, enforced by `tests/obs.rs`).
//!
//! Five pillars:
//!
//! * [`metrics`] — a [`metrics::Registry`] of counters, gauges, and
//!   fixed-bucket histograms behind cheap `Arc`'d handles, rendered as
//!   Prometheus text exposition (`# HELP`/`# TYPE`) or a JSON snapshot
//!   that round-trips. The serving loop keeps a cumulative registry
//!   (`lords_*` families) next to the windowed `ServeMetrics` report.
//! * [`trace`] — structured spans via the [`crate::span!`] macro
//!   (re-exported here, so call sites write `obs::span!`), recorded into
//!   lock-free per-thread buffers and exported as Chrome
//!   `chrome://tracing` JSON (`serve --trace-out trace.json`).
//! * [`flight`] — a bounded ring of per-request lifecycle events
//!   (submitted → admitted → prefill chunks → first token →
//!   done/cancelled/rejected), dumpable on demand and automatically on
//!   anomalies (rejection storm, stall, seal-error breach — thresholds
//!   configurable via `ServeCfg`).
//! * [`quality`] — quantization-quality telemetry: per-layer weight
//!   quant-error gauges, per-tier KV seal-error histograms, the
//!   logit-drift sentinel's agreement/drift families, and KV block-heat
//!   coldness. Observe-only by construction.
//! * [`http`] — [`http::AdminServer`], a background-thread admin endpoint
//!   serving `/metrics`, `/trace`, `/flight`, `/quality`, `/fault`,
//!   `/healthz` (liveness), and `/readyz` (readiness)
//!   live over plain `std::net` (`serve --admin-addr HOST:PORT`).
//!
//! [`json`] underpins the export paths: a minimal JSON value model,
//! parser, and deterministic printer (the vendored dependency set has no
//! `serde`).

pub mod flight;
pub mod http;
pub mod json;
pub mod metrics;
pub mod quality;
pub mod trace;

pub use crate::span;
pub use flight::{FlightEvent, FlightKind, FlightRecorder};
pub use http::AdminServer;
pub use metrics::{Counter, Gauge, Histogram, Registry, Snapshot};
pub use quality::KvSealObs;
pub use trace::{SpanEvent, SpanGuard};
