//! Minimal JSON value model, parser, and printer (the vendored set has no
//! `serde`). Used by the observability exports: the metrics snapshot
//! round-trip, the Chrome-trace writer, and the flight-recorder dump —
//! and by tests to validate that every emitted artifact actually parses.
//!
//! Scope: the full JSON grammar minus float edge cases nobody emits
//! (`NaN`/`Inf` are rejected on print, exponents accepted on parse).
//! Object key order is preserved, so printing is deterministic.

use std::fmt::Write as _;

/// A parsed JSON document.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved (deterministic re-render).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Parse one JSON document (trailing whitespace allowed, nothing else).
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    /// Render compactly (no whitespace); deterministic for a given value.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Write a finite number: integers without a fraction, floats with enough
/// digits to round-trip (`{:?}` on f64 is shortest-round-trip in Rust).
fn write_num(n: f64, out: &mut String) {
    assert!(n.is_finite(), "JSON cannot carry NaN/Inf");
    if n == n.trunc() && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n:?}");
    }
}

/// JSON string escaping: quote, backslash, and control characters.
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: escape into a fresh string (for hand-rolled writers).
pub fn escaped(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    write_escaped(s, &mut out);
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at offset {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.i += 4;
                            // surrogate pairs are out of scope for our own
                            // exports; map lone surrogates to U+FFFD
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (multi-byte safe)
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid UTF-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']' got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                other => return Err(format!("expected ',' or '}}' got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_num(), Some(1.0));
    }

    #[test]
    fn render_parse_round_trip() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("q\"uote\\and\nnewline".into())),
            ("xs".into(), Json::Arr(vec![Json::Num(1.0), Json::Num(0.125), Json::Null])),
        ]);
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }
}
