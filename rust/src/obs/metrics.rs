//! Metrics registry — counters, gauges, and fixed-bucket histograms
//! behind cheap clonable handles.
//!
//! The registry is the slow path: registration (and get-or-register
//! lookup) takes a mutex over a sorted map. The handles it returns are
//! `Arc`-shared plain atomics — incrementing a [`Counter`], setting a
//! [`Gauge`], or observing into a [`Histogram`] is a handful of atomic
//! ops with no lock, safe from any thread. Hot paths cache handles at
//! construction time and never touch the registry again.
//!
//! Two exposition formats, both with deterministic ordering (metrics
//! sorted by name, then by label set):
//!
//! * [`Registry::render_prometheus`] — the Prometheus text format
//!   (`# HELP`/`# TYPE` lines per family, `_bucket`/`_sum`/`_count`
//!   expansion for histograms, label values escaped per the spec). Help
//!   text is optional — the `*_with_help` registration variants record
//!   it once per family, first writer wins.
//! * [`Registry::snapshot`] → [`Snapshot::to_json`] — a JSON document
//!   that [`Snapshot::from_json`] parses back losslessly (round-trip
//!   gated by `tests/obs.rs`).

use super::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing event count.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time level (occupancy, bytes resident, queue depth).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistInner {
    /// Upper bounds of the finite buckets (strictly increasing). An
    /// implicit `+Inf` bucket always follows.
    bounds: Vec<f64>,
    /// One slot per finite bound plus the `+Inf` overflow slot.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observed values, stored as f64 bits (CAS-add).
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram with Prometheus cumulative-`le` semantics: an
/// observation lands in the first bucket whose bound is `>= v`.
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistInner>);

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one finite bucket");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must strictly increase: {bounds:?}"
        );
        Histogram(Arc::new(HistInner {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
        }))
    }

    pub fn observe(&self, v: f64) {
        let h = &self.0;
        let idx = h.bounds.iter().position(|&b| v <= b).unwrap_or(h.bounds.len());
        h.buckets[idx].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = h.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match h.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Per-bucket counts (finite buckets in bound order, then `+Inf`).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn bounds(&self) -> &[f64] {
        &self.0.bounds
    }
}

/// Sorted label pairs — the identity of a metric within its family.
pub type Labels = Vec<(String, String)>;

fn labels_of(pairs: &[(&str, &str)]) -> Labels {
    let mut ls: Labels =
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    ls.sort();
    ls
}

#[derive(Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug, Default)]
struct RegInner {
    /// family name → label set → metric. BTreeMaps give the exposition
    /// its stable ordering for free.
    families: BTreeMap<String, BTreeMap<Labels, Metric>>,
    /// family name → help text (`# HELP` line). Optional; first writer
    /// wins so help stays stable across re-registration.
    help: BTreeMap<String, String>,
}

/// Process-wide metric store (see the module doc).
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<RegInner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register a counter. Repeated calls with the same name and
    /// labels return handles to the same underlying value.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let ls = labels_of(labels);
        let mut reg = self.inner.lock().unwrap();
        let fam = reg.families.entry(name.to_string()).or_default();
        match fam.entry(ls).or_insert_with(|| Metric::Counter(Counter::default())) {
            Metric::Counter(c) => c.clone(),
            other => panic!("metric '{name}' already registered as {}", kind_name(other)),
        }
    }

    /// Get-or-register a gauge.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let ls = labels_of(labels);
        let mut reg = self.inner.lock().unwrap();
        let fam = reg.families.entry(name.to_string()).or_default();
        match fam.entry(ls).or_insert_with(|| Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g.clone(),
            other => panic!("metric '{name}' already registered as {}", kind_name(other)),
        }
    }

    /// Get-or-register a histogram. The first registration fixes the
    /// bucket bounds; later calls return the existing histogram (their
    /// `bounds` argument is ignored).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], bounds: &[f64]) -> Histogram {
        let ls = labels_of(labels);
        let mut reg = self.inner.lock().unwrap();
        let fam = reg.families.entry(name.to_string()).or_default();
        match fam.entry(ls).or_insert_with(|| Metric::Histogram(Histogram::new(bounds))) {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric '{name}' already registered as {}", kind_name(other)),
        }
    }

    /// Like [`Registry::counter`], also recording the family's `# HELP`
    /// text (first registration wins).
    pub fn counter_with_help(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Counter {
        self.set_help(name, help);
        self.counter(name, labels)
    }

    /// Like [`Registry::gauge`], also recording the family's `# HELP` text.
    pub fn gauge_with_help(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Gauge {
        self.set_help(name, help);
        self.gauge(name, labels)
    }

    /// Like [`Registry::histogram`], also recording the family's `# HELP`
    /// text.
    pub fn histogram_with_help(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
        help: &str,
    ) -> Histogram {
        self.set_help(name, help);
        self.histogram(name, labels, bounds)
    }

    /// Record `# HELP` text for a family (first writer wins).
    pub fn set_help(&self, name: &str, help: &str) {
        let mut reg = self.inner.lock().unwrap();
        reg.help.entry(name.to_string()).or_insert_with(|| help.to_string());
    }

    /// Prometheus text exposition (version 0.0.4): optional `# HELP` and
    /// a `# TYPE` line per family, metrics sorted by name then label
    /// set, label values escaped (`\\`, `\"`, `\n`).
    pub fn render_prometheus(&self) -> String {
        let reg = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in &reg.families {
            let kind = fam.values().next().map(kind_name).unwrap_or("gauge");
            if let Some(help) = reg.help.get(name) {
                out.push_str(&format!("# HELP {name} {}\n", help_escape(help)));
            }
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for (labels, metric) in fam {
                match metric {
                    Metric::Counter(c) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            prom_labels(labels, None),
                            c.get()
                        ));
                    }
                    Metric::Gauge(g) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            prom_labels(labels, None),
                            g.get()
                        ));
                    }
                    Metric::Histogram(h) => {
                        let counts = h.bucket_counts();
                        let mut cum = 0u64;
                        for (i, b) in h.bounds().iter().enumerate() {
                            cum += counts[i];
                            out.push_str(&format!(
                                "{name}_bucket{} {cum}\n",
                                prom_labels(labels, Some(&fmt_bound(*b)))
                            ));
                        }
                        cum += counts[h.bounds().len()];
                        out.push_str(&format!(
                            "{name}_bucket{} {cum}\n",
                            prom_labels(labels, Some("+Inf"))
                        ));
                        out.push_str(&format!(
                            "{name}_sum{} {}\n",
                            prom_labels(labels, None),
                            fmt_value(h.sum())
                        ));
                        out.push_str(&format!(
                            "{name}_count{} {}\n",
                            prom_labels(labels, None),
                            h.count()
                        ));
                    }
                }
            }
        }
        out
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> Snapshot {
        let reg = self.inner.lock().unwrap();
        let mut snap = Snapshot::default();
        for (name, fam) in &reg.families {
            for (labels, metric) in fam {
                match metric {
                    Metric::Counter(c) => snap.counters.push(CounterSample {
                        name: name.clone(),
                        labels: labels.clone(),
                        value: c.get(),
                    }),
                    Metric::Gauge(g) => snap.gauges.push(GaugeSample {
                        name: name.clone(),
                        labels: labels.clone(),
                        value: g.get(),
                    }),
                    Metric::Histogram(h) => snap.histograms.push(HistogramSample {
                        name: name.clone(),
                        labels: labels.clone(),
                        bounds: h.bounds().to_vec(),
                        buckets: h.bucket_counts(),
                        sum: h.sum(),
                        count: h.count(),
                    }),
                }
            }
        }
        snap
    }

    /// JSON snapshot (see [`Snapshot::to_json`]).
    pub fn render_json(&self) -> String {
        self.snapshot().to_json()
    }
}

fn kind_name(m: &Metric) -> &'static str {
    match m {
        Metric::Counter(_) => "counter",
        Metric::Gauge(_) => "gauge",
        Metric::Histogram(_) => "histogram",
    }
}

/// `{a="x",le="1"}` with spec escaping; empty string for no labels.
fn prom_labels(labels: &Labels, le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", prom_escape(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn prom_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// `# HELP` escaping per the exposition spec: backslash and newline only
/// (quotes stay literal in help text).
fn help_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Bucket bound formatting: integers bare, floats shortest-round-trip —
/// both stable across runs.
fn fmt_bound(b: f64) -> String {
    fmt_value(b)
}

fn fmt_value(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:?}")
    }
}

// ------------------------------------------------------------- snapshot

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<CounterSample>,
    pub gauges: Vec<GaugeSample>,
    pub histograms: Vec<HistogramSample>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CounterSample {
    pub name: String,
    pub labels: Labels,
    pub value: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct GaugeSample {
    pub name: String,
    pub labels: Labels,
    pub value: i64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSample {
    pub name: String,
    pub labels: Labels,
    /// Finite bucket bounds; `buckets` has one extra `+Inf` slot.
    pub bounds: Vec<f64>,
    pub buckets: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

fn labels_json(labels: &Labels) -> Json {
    Json::Obj(labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect())
}

fn labels_from_json(v: &Json) -> Result<Labels, String> {
    match v {
        Json::Obj(kv) => kv
            .iter()
            .map(|(k, v)| {
                v.as_str()
                    .map(|s| (k.clone(), s.to_string()))
                    .ok_or_else(|| format!("label '{k}' is not a string"))
            })
            .collect(),
        _ => Err("labels must be an object".into()),
    }
}

impl Snapshot {
    /// Deterministic JSON document; [`Snapshot::from_json`] inverts it.
    pub fn to_json(&self) -> String {
        let counters = Json::Arr(
            self.counters
                .iter()
                .map(|c| {
                    Json::Obj(vec![
                        ("name".into(), Json::Str(c.name.clone())),
                        ("labels".into(), labels_json(&c.labels)),
                        ("value".into(), Json::Num(c.value as f64)),
                    ])
                })
                .collect(),
        );
        let gauges = Json::Arr(
            self.gauges
                .iter()
                .map(|g| {
                    Json::Obj(vec![
                        ("name".into(), Json::Str(g.name.clone())),
                        ("labels".into(), labels_json(&g.labels)),
                        ("value".into(), Json::Num(g.value as f64)),
                    ])
                })
                .collect(),
        );
        let histograms = Json::Arr(
            self.histograms
                .iter()
                .map(|h| {
                    Json::Obj(vec![
                        ("name".into(), Json::Str(h.name.clone())),
                        ("labels".into(), labels_json(&h.labels)),
                        (
                            "bounds".into(),
                            Json::Arr(h.bounds.iter().map(|&b| Json::Num(b)).collect()),
                        ),
                        (
                            "buckets".into(),
                            Json::Arr(h.buckets.iter().map(|&c| Json::Num(c as f64)).collect()),
                        ),
                        ("sum".into(), Json::Num(h.sum)),
                        ("count".into(), Json::Num(h.count as f64)),
                    ])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("counters".into(), counters),
            ("gauges".into(), gauges),
            ("histograms".into(), histograms),
        ])
        .render()
    }

    /// Parse a document produced by [`Snapshot::to_json`].
    pub fn from_json(text: &str) -> Result<Snapshot, String> {
        let doc = Json::parse(text)?;
        let mut snap = Snapshot::default();
        for c in doc.get("counters").and_then(Json::as_arr).ok_or("missing counters")? {
            snap.counters.push(CounterSample {
                name: c.get("name").and_then(Json::as_str).ok_or("counter name")?.to_string(),
                labels: labels_from_json(c.get("labels").ok_or("counter labels")?)?,
                value: c.get("value").and_then(Json::as_num).ok_or("counter value")? as u64,
            });
        }
        for g in doc.get("gauges").and_then(Json::as_arr).ok_or("missing gauges")? {
            snap.gauges.push(GaugeSample {
                name: g.get("name").and_then(Json::as_str).ok_or("gauge name")?.to_string(),
                labels: labels_from_json(g.get("labels").ok_or("gauge labels")?)?,
                value: g.get("value").and_then(Json::as_num).ok_or("gauge value")? as i64,
            });
        }
        for h in doc.get("histograms").and_then(Json::as_arr).ok_or("missing histograms")? {
            let nums = |key: &str| -> Result<Vec<f64>, String> {
                h.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| format!("histogram {key}"))?
                    .iter()
                    .map(|v| v.as_num().ok_or_else(|| format!("{key} entry")))
                    .collect()
            };
            snap.histograms.push(HistogramSample {
                name: h.get("name").and_then(Json::as_str).ok_or("histogram name")?.to_string(),
                labels: labels_from_json(h.get("labels").ok_or("histogram labels")?)?,
                bounds: nums("bounds")?,
                buckets: nums("buckets")?.into_iter().map(|v| v as u64).collect(),
                sum: h.get("sum").and_then(Json::as_num).ok_or("histogram sum")?,
                count: h.get("count").and_then(Json::as_num).ok_or("histogram count")? as u64,
            });
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_handles_share_state() {
        let reg = Registry::new();
        let a = reg.counter("ticks", &[]);
        let b = reg.counter("ticks", &[]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = reg.gauge("depth", &[]);
        g.set(7);
        g.add(-2);
        assert_eq!(reg.gauge("depth", &[]).get(), 5);
    }

    #[test]
    fn labels_distinguish_series() {
        let reg = Registry::new();
        reg.counter("req", &[("adapter", "t0")]).inc();
        reg.counter("req", &[("adapter", "t1")]).add(2);
        assert_eq!(reg.counter("req", &[("adapter", "t0")]).get(), 1);
        assert_eq!(reg.counter("req", &[("adapter", "t1")]).get(), 2);
        // label order is not identity
        reg.counter("x", &[("a", "1"), ("b", "2")]).inc();
        assert_eq!(reg.counter("x", &[("b", "2"), ("a", "1")]).get(), 1);
    }

    #[test]
    fn histogram_le_semantics() {
        let reg = Registry::new();
        let h = reg.histogram("lat", &[], &[1.0, 2.0, 4.0]);
        h.observe(1.0); // lands in le=1 (inclusive)
        h.observe(2.5); // le=4
        h.observe(9.0); // +Inf
        assert_eq!(h.bucket_counts(), vec![1, 0, 1, 1]);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 12.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        reg.counter("m", &[]);
        reg.gauge("m", &[]);
    }

    #[test]
    fn help_renders_before_type_and_first_writer_wins() {
        let reg = Registry::new();
        reg.counter_with_help("jobs_total", &[], "Jobs\nprocessed \\ total.").inc();
        reg.counter_with_help("jobs_total", &[], "a different help").inc();
        reg.gauge("plain", &[]).set(1);
        let text = reg.render_prometheus();
        assert!(
            text.contains("# HELP jobs_total Jobs\\nprocessed \\\\ total.\n# TYPE jobs_total counter\n"),
            "{text}"
        );
        // Families registered without help get no # HELP line.
        assert!(text.contains("# TYPE plain gauge\n"));
        assert!(!text.contains("# HELP plain"));
    }
}
