//! [`AdminServer`] — a zero-dependency live admin endpoint.
//!
//! PR 7's exports only land at process exit (`--metrics-out`,
//! `--trace-out`); production scraping needs to observe a *running*
//! server. This module serves the whole observability surface over plain
//! `std::net` — no async runtime, no HTTP crate — from one background
//! accept thread:
//!
//! | route      | payload                                                  |
//! |------------|----------------------------------------------------------|
//! | `/metrics` | live Prometheus render of the shared [`Registry`]        |
//! | `/trace`   | Chrome-trace JSON (drains the global span buffer)        |
//! | `/flight`  | last published flight-ring dump (see [`AdminServer::publish_flight`]) |
//! | `/quality` | quality-telemetry snapshot JSON ([`quality::quality_json`]) |
//! | `/fault`   | fault-plane status JSON ([`crate::fault::status_json`]: specs, checks, fires by site) |
//! | `/healthz` | `ok` — liveness probe (answers as long as the process runs) |
//! | `/readyz`  | readiness probe: `ok` (200), or `draining`/`backpressure` (503) once [`AdminServer::set_ready`] turns it off — drains and sustained queue-full streaks flip it |
//!
//! Everything served from the registry is lock-free for the serving
//! threads (atomic metric handles); `/metrics` and `/quality` therefore
//! render mid-run without any cooperation from the serving loop. The
//! flight ring is single-threaded by design, so the serving loop pushes
//! dumps in with [`AdminServer::publish_flight`] instead.
//!
//! Requests are handled serially on the accept thread (one bounded-size,
//! bounded-time connection at a time — an admin endpoint, not a web
//! server). Dropping the handle stops the thread: the drop sets a stop
//! flag, self-connects to unblock `accept`, and joins.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::metrics::Registry;
use super::{quality, trace};

/// Cap on request head bytes read before answering 400.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Per-connection socket read/write timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

struct Shared {
    registry: Arc<Registry>,
    flight: Mutex<String>,
    stop: AtomicBool,
    /// `/readyz` state: true (default) serves 200, false serves 503 with
    /// the published reason.
    ready: AtomicBool,
    /// why `/readyz` is false ("draining", "backpressure", ...).
    not_ready_reason: Mutex<String>,
}

/// Handle to a running admin endpoint. Dropping it shuts the listener
/// down cleanly.
pub struct AdminServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port — read the
    /// result back with [`Self::local_addr`]) and start serving on a
    /// background thread.
    pub fn bind(addr: &str, registry: Arc<Registry>) -> std::io::Result<AdminServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            registry,
            flight: Mutex::new("{\"events\":[],\"evicted\":0}".to_string()),
            stop: AtomicBool::new(false),
            ready: AtomicBool::new(true),
            not_ready_reason: Mutex::new("not ready".to_string()),
        });
        let accept_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("lords-admin".to_string())
            .spawn(move || accept_loop(&listener, &accept_shared))?;
        Ok(AdminServer { addr: local, shared, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Publish a flight-ring dump for `/flight`. The flight recorder is
    /// single-threaded state owned by the serving loop, so the loop calls
    /// this whenever it has something fresh (periodically, or when an
    /// anomaly trips).
    pub fn publish_flight(&self, dump: String) {
        // the guarded value is a plain String, valid even if a reader
        // panicked mid-clone — recover from poisoning instead of unwinding
        *self.shared.flight.lock().unwrap_or_else(|e| e.into_inner()) = dump;
    }

    /// Flip the `/readyz` probe. The serving loop publishes its
    /// `Server::is_ready` state here (readiness is distinct from
    /// `/healthz` liveness: a draining or backpressured server is alive
    /// but should stop receiving new traffic). `reason` is served in the
    /// 503 body while not ready.
    pub fn set_ready(&self, ready: bool, reason: &str) {
        if !ready {
            *self
                .shared
                .not_ready_reason
                .lock()
                .unwrap_or_else(|e| e.into_inner()) = reason.to_string();
        }
        self.shared.ready.store(ready, Ordering::SeqCst);
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Unblock the accept call. A wildcard bind (0.0.0.0) is not a
        // connectable destination on every platform — aim at loopback.
        let mut target = self.addr;
        if target.ip().is_unspecified() {
            target.set_ip(std::net::Ipv4Addr::LOCALHOST.into());
        }
        let _ = TcpStream::connect_timeout(&target, IO_TIMEOUT);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Shared) {
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = conn {
            let _ = handle_conn(stream, shared);
        }
    }
}

fn handle_conn(mut stream: TcpStream, shared: &Shared) -> std::io::Result<()> {
    if let Some(kind) = crate::fault::point!("http.conn") {
        // the admin plane degrades visibly, never silently: latency holds
        // the connection, every other kind answers 503
        if crate::fault::degrades(kind) {
            return respond(
                &mut stream,
                503,
                "text/plain; charset=utf-8",
                "injected fault\n",
            );
        }
    }
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let Some((method, path)) = read_request_line(&mut stream) else {
        return respond(&mut stream, 400, "text/plain; charset=utf-8", "bad request\n");
    };
    if method != "GET" {
        return respond(&mut stream, 405, "text/plain; charset=utf-8", "GET only\n");
    }
    match path.as_str() {
        "/healthz" => respond(&mut stream, 200, "text/plain; charset=utf-8", "ok\n"),
        "/readyz" => {
            if shared.ready.load(Ordering::SeqCst) {
                respond(&mut stream, 200, "text/plain; charset=utf-8", "ok\n")
            } else {
                let reason =
                    shared.not_ready_reason.lock().unwrap_or_else(|e| e.into_inner()).clone();
                respond(&mut stream, 503, "text/plain; charset=utf-8", &format!("{reason}\n"))
            }
        }
        "/fault" => {
            respond(&mut stream, 200, "application/json", &crate::fault::status_json())
        }
        "/metrics" => respond(
            &mut stream,
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            &shared.registry.render_prometheus(),
        ),
        "/trace" => {
            respond(&mut stream, 200, "application/json", &trace::render_chrome(&trace::drain()))
        }
        "/flight" => {
            let body = shared.flight.lock().unwrap_or_else(|e| e.into_inner()).clone();
            respond(&mut stream, 200, "application/json", &body)
        }
        "/quality" => {
            let body = quality::quality_json(&shared.registry).render();
            respond(&mut stream, 200, "application/json", &body)
        }
        _ => respond(&mut stream, 404, "text/plain; charset=utf-8", "not found\n"),
    }
}

/// Read up to the end of the request head (bounded) and parse the
/// request line into (method, path). Query strings are dropped.
fn read_request_line(stream: &mut TcpStream) -> Option<(String, String)> {
    let mut buf = Vec::with_capacity(256);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk).ok()?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.len() > MAX_REQUEST_BYTES {
            return None;
        }
        if buf.windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?.to_string();
    let target = parts.next()?;
    let path = target.split('?').next().unwrap_or(target).to_string();
    Some((method, path))
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::json::Json;

    fn fetch(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect to admin endpoint");
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn get(addr: SocketAddr, path: &str) -> String {
        fetch(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n"))
    }

    #[test]
    fn serves_routes_and_shuts_down_on_drop() {
        let reg = Arc::new(Registry::new());
        reg.counter("demo_total", &[]).add(3);
        let admin = AdminServer::bind("127.0.0.1:0", Arc::clone(&reg)).expect("bind port 0");
        let addr = admin.local_addr();

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        // readiness defaults to ok, flips to 503 with the published
        // reason, and flips back — liveness stays 200 throughout
        let ready = get(addr, "/readyz");
        assert!(ready.starts_with("HTTP/1.1 200 OK\r\n"), "{ready}");
        admin.set_ready(false, "draining");
        let ready = get(addr, "/readyz");
        assert!(ready.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{ready}");
        assert!(ready.ends_with("draining\n"), "{ready}");
        assert!(get(addr, "/healthz").starts_with("HTTP/1.1 200"), "liveness unaffected");
        admin.set_ready(true, "");
        assert!(get(addr, "/readyz").starts_with("HTTP/1.1 200"));

        let fault = get(addr, "/fault");
        assert!(fault.starts_with("HTTP/1.1 200 OK\r\n"), "{fault}");
        let fault_body = fault.split("\r\n\r\n").nth(1).expect("fault body");
        Json::parse(fault_body).expect("fault JSON parses");
        assert!(fault_body.contains("\"enabled\""), "{fault_body}");

        let metrics = get(addr, "/metrics");
        assert!(metrics.contains("demo_total 3"), "{metrics}");

        let quality = get(addr, "/quality");
        let body = quality.split("\r\n\r\n").nth(1).expect("body");
        Json::parse(body).expect("quality JSON parses");

        admin.publish_flight("{\"events\":[],\"evicted\":7}".to_string());
        let flight = get(addr, "/flight");
        assert!(flight.contains("\"evicted\":7"), "{flight}");

        assert!(get(addr, "/nope").starts_with("HTTP/1.1 404"));
        let post = fetch(addr, "POST /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n");
        assert!(post.starts_with("HTTP/1.1 405"), "{post}");

        drop(admin);
        // The listener is gone: a fresh connection must fail or be refused.
        assert!(
            TcpStream::connect_timeout(&addr, Duration::from_millis(500)).is_err(),
            "admin listener should stop accepting after drop"
        );
    }
}
