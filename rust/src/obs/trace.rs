//! Structured tracing spans with Chrome-trace export.
//!
//! Design constraints, in order:
//!
//! 1. **Off must be near-free.** Tracing is gated on one global
//!    `AtomicBool`; when off, `span!` costs a relaxed load and returns an
//!    inert guard — no clock read, no TLS touch, no allocation.
//! 2. **On must not perturb results.** Recording never takes a lock on
//!    the hot path (locks could reorder thread interleavings enough to
//!    change timing-sensitive scheduling): each thread appends into its
//!    own single-producer segment chain, and readers only observe slots
//!    the producer has published. Token streams stay bitwise identical
//!    with tracing enabled — `tests/obs.rs` enforces this.
//! 3. **Zero dependencies.** The export path writes Chrome
//!    `chrome://tracing` JSON (load via `chrome://tracing` or
//!    <https://ui.perfetto.dev>) through [`super::json`].
//!
//! The per-thread buffer is an append-only chain of fixed 4096-slot
//! segments. The producer writes a slot, then publishes it with a
//! release store of the segment length; [`drain`] acquire-loads the
//! length and copies only the published prefix, so no slot is ever read
//! while being written and none is ever rewritten. The segment list is
//! behind a mutex, but the producer takes it only once per 4096 spans
//! (segment allocation) and readers only during [`drain`]. Buffers are
//! `Arc`-retained by a global registry so spans emitted by short-lived
//! pool workers survive thread exit. Each thread's chain is bounded
//! (64 segments ≈ 256k spans); past the bound, spans are counted as
//! dropped ([`dropped`]) rather than grown without limit.

use super::json::Json;
use std::cell::{RefCell, UnsafeCell};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Spans per segment; one mutex acquisition per this many records.
const SEG_CAP: usize = 4096;
/// Per-thread bound: 64 segments ≈ 256k spans (~10 MiB). Beyond it spans
/// are dropped (and counted), not silently lost.
const MAX_SEGMENTS: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the first observability clock read in this process.
/// One shared epoch keeps timestamps from different threads comparable.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_nanos() as u64
}

/// One completed span, as recorded (copied out by [`drain`]).
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Recording thread (stable small integer, not the OS tid).
    pub tid: u64,
    pub t0_ns: u64,
    pub dur_ns: u64,
    /// One free scalar of context (batch size, token count, ...).
    pub arg: u64,
}

#[derive(Clone, Copy)]
struct SpanRecord {
    name: &'static str,
    t0_ns: u64,
    dur_ns: u64,
    arg: u64,
}

const EMPTY: SpanRecord = SpanRecord { name: "", t0_ns: 0, dur_ns: 0, arg: 0 };

struct Segment {
    /// Published record count; slots `< len` are immutable and readable.
    len: AtomicUsize,
    slots: Vec<UnsafeCell<SpanRecord>>,
}

// SAFETY: slots are written only by the single owning producer thread, and
// only at index `len`; the producer publishes each write with a release
// store of `len`, and readers touch only indices below an acquire-loaded
// `len`. A published slot is never written again.
unsafe impl Sync for Segment {}
unsafe impl Send for Segment {}

impl Segment {
    fn new() -> Arc<Segment> {
        Arc::new(Segment {
            len: AtomicUsize::new(0),
            slots: (0..SEG_CAP).map(|_| UnsafeCell::new(EMPTY)).collect(),
        })
    }
}

struct ThreadBuf {
    tid: u64,
    segments: Mutex<Vec<Arc<Segment>>>,
    /// Records already consumed by [`drain`] (reader-side cursor).
    drained: AtomicUsize,
    dropped: AtomicU64,
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static BUFS: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    BUFS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Producer-side handle: the thread's buffer plus its open segment, so the
/// common record path touches no lock at all.
struct Writer {
    buf: Arc<ThreadBuf>,
    cur: Arc<Segment>,
}

thread_local! {
    static WRITER: RefCell<Option<Writer>> = const { RefCell::new(None) };
}

fn record(name: &'static str, t0_ns: u64, dur_ns: u64, arg: u64) {
    WRITER.with(|w| {
        let mut w = w.borrow_mut();
        let writer = w.get_or_insert_with(|| {
            static NEXT_TID: AtomicU64 = AtomicU64::new(0);
            let seg = Segment::new();
            let buf = Arc::new(ThreadBuf {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                segments: Mutex::new(vec![seg.clone()]),
                drained: AtomicUsize::new(0),
                dropped: AtomicU64::new(0),
            });
            registry().lock().unwrap().push(buf.clone());
            Writer { buf, cur: seg }
        });
        let mut n = writer.cur.len.load(Ordering::Relaxed);
        if n == SEG_CAP {
            let mut segs = writer.buf.segments.lock().unwrap();
            if segs.len() == MAX_SEGMENTS {
                writer.buf.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            let seg = Segment::new();
            segs.push(seg.clone());
            drop(segs);
            writer.cur = seg;
            n = 0;
        }
        // SAFETY: this thread is the only producer for `cur`, and slot `n`
        // is unpublished (n == len). The release store below publishes it.
        unsafe {
            *writer.cur.slots[n].get() = SpanRecord { name, t0_ns, dur_ns, arg };
        }
        writer.cur.len.store(n + 1, Ordering::Release);
    });
}

/// RAII span: records `[begin, drop)` into the calling thread's buffer.
/// Prefer the [`crate::span!`] macro over calling this directly.
pub struct SpanGuard {
    name: &'static str,
    t0_ns: u64,
    arg: u64,
    live: bool,
}

impl SpanGuard {
    #[inline]
    pub fn begin(name: &'static str, arg: u64) -> SpanGuard {
        if !enabled() {
            return SpanGuard { name, t0_ns: 0, arg, live: false };
        }
        SpanGuard { name, t0_ns: now_ns(), arg, live: true }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            record(self.name, self.t0_ns, now_ns().saturating_sub(self.t0_ns), self.arg);
        }
    }
}

/// Open a span for the enclosing scope:
/// `let _s = obs::span!("decode");` or `obs::span!("decode", batch as u64)`.
/// The guard records on drop; binding it to `_` drops immediately.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::SpanGuard::begin($name, 0)
    };
    ($name:expr, $arg:expr) => {
        $crate::obs::trace::SpanGuard::begin($name, $arg as u64)
    };
}

/// Copy out every span published since the previous `drain` call, across
/// all threads that ever recorded, ordered by start time.
pub fn drain() -> Vec<SpanEvent> {
    let bufs: Vec<Arc<ThreadBuf>> = registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for buf in bufs {
        let segs: Vec<Arc<Segment>> = buf.segments.lock().unwrap().clone();
        let mut skip = buf.drained.load(Ordering::Relaxed);
        let mut consumed = skip;
        for seg in segs {
            let n = seg.len.load(Ordering::Acquire);
            if skip >= n {
                skip -= n;
                continue;
            }
            for i in skip..n {
                // SAFETY: slots below the acquire-loaded `len` are
                // published and never rewritten.
                let r = unsafe { *seg.slots[i].get() };
                out.push(SpanEvent {
                    name: r.name,
                    tid: buf.tid,
                    t0_ns: r.t0_ns,
                    dur_ns: r.dur_ns,
                    arg: r.arg,
                });
            }
            consumed += n - skip;
            skip = 0;
        }
        buf.drained.store(consumed, Ordering::Relaxed);
    }
    out.sort_by_key(|e| (e.t0_ns, e.tid));
    out
}

/// Total spans discarded because a thread hit its buffer bound.
pub fn dropped() -> u64 {
    registry().lock().unwrap().iter().map(|b| b.dropped.load(Ordering::Relaxed)).sum()
}

/// Aggregate spans by name: `(name, count, total_ns)`, sorted by name.
pub fn phase_totals(spans: &[SpanEvent]) -> Vec<(String, u64, u64)> {
    let mut agg: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for s in spans {
        let e = agg.entry(s.name).or_insert((0, 0));
        e.0 += 1;
        e.1 += s.dur_ns;
    }
    agg.into_iter().map(|(name, (n, ns))| (name.to_string(), n, ns)).collect()
}

/// Render spans as a Chrome-trace (`chrome://tracing`) JSON document:
/// complete (`"ph":"X"`) events with microsecond timestamps.
pub fn render_chrome(spans: &[SpanEvent]) -> String {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            Json::Obj(vec![
                ("name".into(), Json::Str(s.name.to_string())),
                ("ph".into(), Json::Str("X".into())),
                ("pid".into(), Json::Num(0.0)),
                ("tid".into(), Json::Num(s.tid as f64)),
                ("ts".into(), Json::Num(s.t0_ns as f64 / 1000.0)),
                ("dur".into(), Json::Num(s.dur_ns as f64 / 1000.0)),
                ("args".into(), Json::Obj(vec![("arg".into(), Json::Num(s.arg as f64))])),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
    .render()
}

/// Write [`render_chrome`] output to a file.
pub fn write_chrome(path: &str, spans: &[SpanEvent]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(render_chrome(spans).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enabled flag and the drain cursors are process-global, so the
    /// tests that toggle or drain them must not interleave.
    static SERIAL: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_spans_record_nothing() {
        let _serial = SERIAL.lock().unwrap();
        set_enabled(false);
        {
            let _s = crate::span!("trace_test_disabled");
        }
        assert!(drain().iter().all(|e| e.name != "trace_test_disabled"));
    }

    #[test]
    fn spans_record_and_drain_once() {
        let _serial = SERIAL.lock().unwrap();
        set_enabled(true);
        {
            let _s = crate::span!("trace_test_basic", 7);
        }
        set_enabled(false);
        let mine: Vec<SpanEvent> =
            drain().into_iter().filter(|e| e.name == "trace_test_basic").collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].arg, 7);
        // cursor advanced: a second drain must not replay it
        assert!(drain().iter().all(|e| e.name != "trace_test_basic"));
    }

    #[test]
    fn cross_thread_spans_survive_thread_exit() {
        let _serial = SERIAL.lock().unwrap();
        set_enabled(true);
        std::thread::spawn(|| {
            let _s = crate::span!("trace_test_worker");
        })
        .join()
        .unwrap();
        set_enabled(false);
        let spans = drain();
        assert!(spans.iter().any(|e| e.name == "trace_test_worker"));
    }

    #[test]
    fn chrome_render_parses_and_totals_add_up() {
        let spans = vec![
            SpanEvent { name: "a", tid: 0, t0_ns: 1_000, dur_ns: 2_000, arg: 1 },
            SpanEvent { name: "a", tid: 1, t0_ns: 4_000, dur_ns: 1_000, arg: 2 },
            SpanEvent { name: "b", tid: 0, t0_ns: 2_500, dur_ns: 500, arg: 0 },
        ];
        let doc = Json::parse(&render_chrome(&spans)).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("ts").unwrap().as_num(), Some(1.0));
        let totals = phase_totals(&spans);
        assert_eq!(totals, vec![("a".into(), 2, 3_000), ("b".into(), 1, 500)]);
    }
}
