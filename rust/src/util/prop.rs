//! Tiny property-based testing harness (a `proptest` substitute — the
//! vendored crate set has none).
//!
//! Usage mirrors the subset of proptest this crate needs:
//!
//! ```ignore
//! prop_check(128, |g| {
//!     let n = g.usize(1..=64);
//!     let xs = g.vec_f32(n, -1.0..1.0);
//!     // ... assert invariant, or return Err(msg) ...
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness re-runs with the failing seed and reports it so
//! the case can be pinned in a regression test. Shrinking is deliberately
//! minimal (we shrink sizes, not values): generators draw sizes from a
//! budget that the harness retries at smaller budgets on failure.

use super::rng::Rng;

/// Value generator handed to property closures.
pub struct Gen {
    rng: Rng,
    /// Size budget in [0, 1]; generators scale their ranges by it so the
    /// harness can retry failures at smaller sizes ("shrinking-lite").
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    pub fn usize(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let hi_eff = lo + (((hi - lo) as f64 * self.size).round() as usize);
        lo + self.rng.below(hi_eff - lo + 1)
    }

    pub fn i32(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi - lo + 1) as usize) as i32
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` against `cases` random cases; panics with the failing seed.
///
/// The base seed is fixed (deterministic CI) but can be overridden with
/// `LORDS_PROP_SEED` to explore more of the space locally.
pub fn prop_check<F>(cases: u32, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base: u64 = std::env::var("LORDS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // shrinking-lite: retry at smaller size budgets to find a
            // smaller counterexample before reporting.
            for &size in &[0.1, 0.25, 0.5] {
                let mut g2 = Gen::new(seed, size);
                if let Err(msg2) = prop(&mut g2) {
                    panic!(
                        "property failed (seed={seed:#x}, size={size}): {msg2}\n\
                         reproduce with Gen::new({seed:#x}, {size})"
                    );
                }
            }
            panic!("property failed (seed={seed:#x}): {msg}\nreproduce with Gen::new({seed:#x}, 1.0)");
        }
    }
}

/// Convenience: assert closeness with a relative+absolute tolerance.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Max |a - b| across slices (∞ if lengths differ).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    if a.len() != b.len() {
        return f32::INFINITY;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// assert_allclose for slices with a helpful message.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            close(*x, *y, rtol, atol),
            "{what}: element {i}: {x} vs {y} (diff {})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(64, |g| {
            let n = g.usize(1..=32);
            let xs = g.vec_f32(n, -1.0, 1.0);
            if xs.iter().all(|v| v.abs() <= 1.0) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        prop_check(64, |g| {
            let v = g.f32(0.0, 1.0);
            if v < 0.5 {
                Ok(())
            } else {
                Err(format!("v={v}"))
            }
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 1e-5));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }
}
