//! Minimal leveled logger (the vendored set has no `env_logger`).
//!
//! Level comes from `LORDS_LOG` (error|warn|info|debug|trace), default info.
//! Use the `info!`/`warn!`/`debug!` macros exported at crate root.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("LORDS_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error) && enabled(Level::Warn) && !enabled(Level::Info));
        set_level(Level::Info);
    }
}
