//! A small fixed-size thread pool with scoped parallel-for, used by the
//! blocked GEMM, the quantizers, and the benchmark harness.
//!
//! Design: one global pool (`ThreadPool::global()`) sized to the machine,
//! channel-fed workers, and a `scope`-free `parallel_for` that splits an
//! index range into chunks and blocks until all chunks complete. Closures
//! are `Send + Sync` and borrow only `&self`-style shared state; mutable
//! output is handled by giving each chunk a disjoint slice (see
//! `tensor::gemm` for the canonical pattern).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Sender<Job>,
    size: usize,
    _handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx, size, _handles: handles }
    }

    /// The process-wide pool, sized to the available parallelism.
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            ThreadPool::new(n)
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(chunk_start, chunk_end)` over `[0, n)` split into ≤ `size`
    /// contiguous chunks, blocking until all complete.
    ///
    /// Safety contract: `f` must be safe to call concurrently on disjoint
    /// ranges. The closure is smuggled across threads with a raw pointer and
    /// joined before return, so borrowed data outlives all uses.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.size.min(n);
        if chunks == 1 {
            f(0, n);
            return;
        }
        let counter = Arc::new((Mutex::new(chunks), Condvar::new()));
        // Erase the borrow: workers finish before this frame returns.
        let f_ptr = &f as *const F as usize;
        let chunk = n.div_ceil(chunks);
        for c in 0..chunks {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            let counter = Arc::clone(&counter);
            let job: Job = Box::new(move || {
                // SAFETY: `f` lives on the caller's frame, and the caller
                // blocks on the completion counter below until every job has
                // run — the borrow is alive for every dereference, and `F:
                // Sync` makes the shared `&F` sound across workers.
                let f = unsafe { &*(f_ptr as *const F) };
                if start < end {
                    f(start, end);
                }
                let (lock, cv) = &*counter;
                let mut left = lock.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            });
            self.tx.send(job).unwrap();
        }
        let (lock, cv) = &*counter;
        let mut left = lock.lock().unwrap();
        while *left > 0 {
            left = cv.wait(left).unwrap();
        }
    }

    /// Map `f` over `0..n` collecting results (order preserved).
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = vec![T::default(); n];
        {
            let out_ptr = SharedMut(out.as_mut_ptr());
            let out_ref = &out_ptr;
            self.parallel_for(n, move |lo, hi| {
                for i in lo..hi {
                    // SAFETY: chunks partition [0, n) disjointly, so index
                    // `i` is written by exactly one worker; `out` is not
                    // touched again until parallel_for joins all workers.
                    unsafe { *out_ref.0.add(i) = f(i) };
                }
            });
        }
        out
    }
}

/// Raw-pointer smuggler for `parallel_for` writers.
///
/// # Safety contract (the single audited justification — reuse this type
/// instead of re-declaring private copies)
///
/// The wrapped pointer may be shared across worker threads only when every
/// worker writes a range disjoint from all others (disjoint output rows,
/// word-aligned packed rows, disjoint column slices, ...), and
/// `parallel_for` joins all workers before the owning buffer is touched
/// again — both upheld by construction at each call site.
pub struct SharedMut<T>(pub *mut T);
// SAFETY: per the contract above — workers write strictly disjoint ranges
// through the pointer, and `parallel_for` joins them before the owning
// buffer is read or dropped, so sharing/sending it cannot race.
unsafe impl<T> Sync for SharedMut<T> {}
unsafe impl<T> Send for SharedMut<T> {}

/// A simple atomic work counter for dynamic load-balancing loops.
pub struct WorkQueue {
    next: AtomicUsize,
    end: usize,
}

impl WorkQueue {
    pub fn new(n: usize) -> Self {
        WorkQueue { next: AtomicUsize::new(0), end: n }
    }

    pub fn take(&self, grain: usize) -> Option<(usize, usize)> {
        let start = self.next.fetch_add(grain, Ordering::Relaxed);
        if start >= self.end {
            None
        } else {
            Some((start, (start + grain).min(self.end)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_range_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out[7], 49);
        assert_eq!(out[99], 9801);
    }

    #[test]
    fn empty_and_single() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_, _| panic!("must not run"));
        let ran = AtomicU64::new(0);
        pool.parallel_for(1, |lo, hi| {
            assert_eq!((lo, hi), (0, 1));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn work_queue_partitions() {
        let q = WorkQueue::new(103);
        let mut seen = vec![false; 103];
        while let Some((lo, hi)) = q.take(10) {
            for i in lo..hi {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn global_pool_is_singleton() {
        let a = ThreadPool::global() as *const _;
        let b = ThreadPool::global() as *const _;
        assert_eq!(a, b);
    }
}
