//! Deterministic pseudo-random numbers: PCG-XSH-RR 64/32 with SplitMix64
//! seeding, plus the distribution helpers the rest of the crate needs
//! (uniform, standard normal, Zipf, categorical, choice-without-replacement).
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is seeded,
//! and the paper-table benches must be re-runnable bit-for-bit.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small state, excellent statistical
/// quality, trivially seedable — the reference generator for this crate.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seed via SplitMix64 so low-entropy seeds (0, 1, 2...) still produce
    /// well-separated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let mut rng = Rng { state: 0, inc: next() | 1 };
        rng.state = next();
        rng.next_u32();
        rng
    }

    /// Derive an independent stream (for per-thread / per-module RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our sizes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (no caching — simplicity over speed;
    /// bulk init paths use `fill_normal`).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Student-t with `dof` degrees of freedom — heavy-tail generator used to
    /// synthesize LLM-like outlier weights.
    pub fn student_t(&mut self, dof: f32) -> f32 {
        // t = N / sqrt(ChiSq/k); ChiSq(k) ~ 2*Gamma(k/2)
        let n = self.normal();
        let mut chi = 0.0f32;
        let k = dof.round().max(1.0) as usize;
        for _ in 0..k {
            let z = self.normal();
            chi += z * z;
        }
        n / (chi / dof).sqrt().max(1e-6)
    }

    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = mean + std * self.normal();
        }
    }

    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (s≈1 ⇒ natural
    /// language token frequencies). O(log n) via inverse-CDF on a cached
    /// harmonic table is overkill here; rejection-free approximation via
    /// the standard inverse transform for the Zipf-Mandelbrot tail.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse transform on the continuous approximation; ranks are
        // 1-based in the CDF, shifted to 0-based indices on return.
        let u = self.f64();
        let rank = if (s - 1.0).abs() < 1e-9 {
            let hn = ((n + 1) as f64).ln();
            (u * hn).exp()
        } else {
            let t = (((n + 1) as f64).powf(1.0 - s) - 1.0) * u + 1.0;
            t.powf(1.0 / (1.0 - s))
        };
        (rank as usize).saturating_sub(1).min(n - 1)
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<u32> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 1);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[r.zipf(100, 1.1)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
    }

    #[test]
    fn choose_is_distinct() {
        let mut r = Rng::new(5);
        let picked = r.choose(50, 20);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(picked.iter().all(|&i| i < 50));
    }

    #[test]
    fn student_t_heavier_than_normal() {
        let mut r = Rng::new(11);
        let n = 30_000;
        let big_t = (0..n).filter(|_| r.student_t(3.0).abs() > 4.0).count();
        let big_n = (0..n).filter(|_| r.normal().abs() > 4.0).count();
        assert!(big_t > big_n * 3, "t tails {big_t} vs normal {big_n}");
    }
}
