//! Foundation utilities: deterministic RNG, a work-stealing-ish thread pool,
//! timing/statistics helpers, a tiny logger, and a property-testing harness.
//!
//! These exist because the build is fully offline: only the `xla` crate's
//! dependency closure is vendored, so `rand`, `rayon`, `proptest`, `log` etc.
//! are re-implemented here at the scale this project needs.

pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

pub use pool::{SharedMut, ThreadPool};
pub use rng::Rng;
pub use stats::Summary;
