//! Streaming summary statistics + timing helpers shared by the eval and
//! bench harnesses (mean / std / percentiles / throughput).

use std::time::{Duration, Instant};

/// Accumulates samples and reports summary statistics.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Wall-clock timer with split support.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.p50() - 3.0).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert!((s.percentile(25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    #[test]
    fn timed_measures() {
        let (v, secs) = timed(|| {
            std::thread::sleep(Duration::from_millis(10));
            42
        });
        assert_eq!(v, 42);
        assert!(secs >= 0.009);
    }
}
