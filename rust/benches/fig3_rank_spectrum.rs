//! Figure 3 — singular-value spectrum of the weight update ΔW after PEFT:
//! QLoRA's additive update truncates exactly at its rank; LoRDS's
//! multiplicative update Q ⊙ (B'A' − BA) spreads over the full dimension
//! (long tail), despite the same trainable budget.
//!
//! Output: normalized singular values σ_i/σ_1 at log-spaced indices plus
//! the effective rank (count of σ_i > 1e-3 σ_1).

use lords::bench::harness::banner;
use lords::bench::TableBuilder;
use lords::config::TrainCfg;
use lords::linalg::svd;
use lords::model::LinearWeight;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::train::{NativeTrainer, TrainKind};

fn main() {
    lords::util::logging::init();
    banner("Figure 3", "ΔW singular spectrum after PEFT (first wq)");

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let target = lords::data::corpus::Corpus::generate(
        lords::data::corpus::CorpusKind::Ptb,
        cfg.vocab,
        60_000,
        5_000,
        77,
    );
    let rank = 16;
    let steps = if full { 150 } else { 50 };
    let tcfg = TrainCfg { steps, batch: 8, seq: 64, peak_lr: 1e-3, ..Default::default() };
    let cb = Codebook::normal_float(4);

    let mut rows: Vec<(String, Vec<f32>, usize)> = Vec::new();
    for method in ["QLoRA", "LoRDS"] {
        let mut model = tb.model.clone();
        // effective weight before adaptation
        let w_before = model.layers[0].wq.effective();
        match method {
            "QLoRA" => model.quantize_qlora(cfg.block, rank, &cb, 0),
            _ => model.quantize_lords(
                cfg.block,
                &cb,
                RefineCfg { steps: 60, ..Default::default() },
                false,
            ),
        }
        let w_q = model.layers[0].wq.effective(); // post-quant pre-peft
        let mut tr = NativeTrainer::new(tcfg.clone(), TrainKind::Peft);
        tr.run(&mut model, &target);
        let w_after = model.layers[0].wq.effective();
        let dw = w_after.sub(&w_q);
        let sv = svd(&dw).s;
        let s1 = sv[0].max(1e-20);
        let eff = sv.iter().filter(|&&s| s > 1e-3 * s1).count();
        let norm: Vec<f32> = sv.iter().map(|&s| s / s1).collect();
        eprintln!(
            "[fig3] {method}: ΔW‖F {:.4} (rel {:.4}), effective rank {eff}/{}",
            dw.frob_norm(),
            dw.frob_norm() / w_before.frob_norm(),
            sv.len()
        );
        rows.push((method.to_string(), norm, eff));
    }

    // spectrum series at log-spaced indices
    let d = rows[0].1.len();
    let idxs: Vec<usize> = {
        let mut v = vec![0usize, 1, 2, 4, 8, rank - 1, rank, rank + 1];
        let mut k = rank * 2;
        while k < d {
            v.push(k);
            k *= 2;
        }
        v.push(d - 1);
        v.retain(|&i| i < d);
        v.dedup();
        v
    };
    let mut headers = vec!["Method".to_string(), "eff.rank".to_string()];
    headers.extend(idxs.iter().map(|i| format!("σ{}", i + 1)));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = TableBuilder::new("Figure 3 — normalized singular values of ΔW").headers(&headers_ref);
    for (method, norm, eff) in &rows {
        let mut row = vec![method.clone(), format!("{eff}/{d}")];
        row.extend(idxs.iter().map(|&i| format!("{:.4}", norm[i])));
        t.row(row);
    }
    t.print();
    lords::bench::baseline::write_tables(
        "fig3_rank_spectrum",
        "BENCH_fig3_rank_spectrum.json",
        full,
        &[t],
    );
    println!("\n(shape check: QLoRA σ collapses ~0 right after σ{rank}; LoRDS keeps a long tail)");
}
