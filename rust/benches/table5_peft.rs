//! Table 5 — quantized PEFT: QLoRA vs LoftQ-init vs LoRDS, fine-tuned on a
//! shifted-distribution corpus (the Commonsense-170k role) and scored on
//! the task suite built from that target distribution.
//!
//! Expected shape: LoRDS > LoftQ > QLoRA on the average with *half* the
//! float-parameter budget (the B/A factors are the only side-car, no
//! additive adapter on top of block scales).

use lords::bench::table::{f2, thousands};
use lords::bench::TableBuilder;
use lords::config::TrainCfg;
use lords::data::corpus::{Corpus, CorpusKind};
use lords::data::TaskSuite;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::train::{NativeTrainer, TrainKind};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 5", "quantized PEFT on a distribution shift");

    let full = full_mode();
    let zoo = model_zoo();
    let models: Vec<_> = if full { zoo } else { zoo.into_iter().take(1).collect() };
    let pretrain = if full { 300 } else { 120 };
    let peft_steps = if full { 200 } else { 60 };
    let block = 64;
    let rank = 16; // adapters' rank (paper: 32 at 8B scale)

    let mut tables = Vec::new();
    for (name, cfg) in &models {
        let tb = Testbed::build(name, cfg, pretrain, 0);
        // target distribution + its task suite
        let target = Corpus::generate(CorpusKind::Ptb, cfg.vocab, 100_000, 20_000, 4242);
        let mut suite = TaskSuite::generate(&target, if full { 40 } else { 16 }, 5);
        for t in suite.tasks.iter_mut() {
            t.examples.truncate(if full { 40 } else { 16 });
        }

        let mut t = TableBuilder::new(&format!("Table 5 — {name} (PEFT on shifted corpus)"))
            .headers(&["Method", "#Train", "#Float", "Target PPL ↓", "Avg ↑"]);

        let cb = Codebook::normal_float(4);
        let tcfg = TrainCfg {
            steps: peft_steps,
            batch: 8,
            seq: 64,
            peak_lr: 1e-3,
            warmup_ratio: 0.05,
            weight_decay: 0.0,
            seed: 0,
            log_every: 1000,
        };

        for method in ["QLoRA", "LoftQ", "LoRDS"] {
            let mut model = tb.model.clone();
            match method {
                "QLoRA" => model.quantize_qlora(block, rank, &cb, 0),
                "LoftQ" => model.map_linears(|w| {
                    let a = lords::quant::baselines::loftq_quantize(w, block, rank, 5, &cb);
                    lords::model::LinearWeight::Qlora(lords::quant::baselines::QloraLinear {
                        base: a.base,
                        lora_a: a.lora_a,
                        lora_b: a.lora_b,
                        scaling: 1.0,
                    })
                }),
                // Table 5 protocol: LoRDS trains at the same rank as the
                // adapters (the paper equalizes #Train, not scale-parity)
                _ => model.quantize_lords_rank(
                    block,
                    rank,
                    &cb,
                    RefineCfg { steps: if full { 200 } else { 60 }, lr: 0.05, requant_every: 5 },
                ),
            }
            let mut tr = NativeTrainer::new(tcfg.clone(), TrainKind::Peft);
            tr.run(&mut model, &target);
            let ppl = lords::eval::perplexity(&model, &target, 64, 8);
            let acc = lords::eval::evaluate_suite(&model, &suite);
            eprintln!(
                "[table5] {name} {method:<6} target PPL {:>8} avg {:.2} (#train {})",
                ppl.display(),
                acc.average,
                model.train_params()
            );
            t.row(vec![
                method.into(),
                thousands(model.train_params()),
                thousands(model.float_params()),
                ppl.display(),
                f2(acc.average),
            ]);
        }
        t.print();
        tables.push(t);
    }
    lords::bench::baseline::write_tables("table5_peft", "BENCH_table5_peft.json", full, &tables);
    println!("\n(shape check: LoRDS wins Avg with ~half the #Float of the adapter methods)");
}
