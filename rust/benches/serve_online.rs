//! Online serving bench — streaming latency under open-loop load through
//! the sessioned submit/step API: TTFT / ITL / queue-wait percentiles and
//! goodput at deterministic Poisson-like arrival rates, for dense f32 vs
//! 8-bit vs 4-bit packed KV over the fused LoRDS base.
//!
//! Protocol: a closed-loop `run_trace` first measures each format's peak
//! request rate; the open-loop driver then replays the workload at ~50%
//! and ~90% of that rate. At 0.5x the server keeps up and ITL ≈ the
//! decode step; at 0.9x the queue forms and TTFT p99 shows the kvquant
//! concurrency headroom (quantized KV admits more sequences per byte, so
//! it degrades later).
//!
//! Results are written to `BENCH_serve_online.json` (override with
//! `LORDS_BENCH_JSON=path`).

use lords::config::ServeCfg;
use lords::coordinator::{run_open_loop, NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvQuantCfg};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::util::Rng;

struct Point {
    kv_bits: u32,
    rate_frac: f64,
    rate_rps: f64,
    completed: usize,
    total_tps: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    itl_p50_ms: f64,
    itl_p99_ms: f64,
    queue_p50_ms: f64,
    queue_p99_ms: f64,
    span_admit_ms: f64,
    span_prefill_ms: f64,
    span_decode_ms: f64,
}

/// Total time spent inside each server tick phase, from the tracing
/// spans recorded during one open-loop run (milliseconds).
fn tick_phase_ms(spans: &[lords::obs::SpanEvent]) -> (f64, f64, f64) {
    let (mut admit, mut prefill, mut decode) = (0u64, 0u64, 0u64);
    for s in spans {
        match s.name {
            "server.admit" => admit += s.dur_ns,
            "server.prefill" => prefill += s.dur_ns,
            "server.decode" => decode += s.dur_ns,
            _ => {}
        }
    }
    (admit as f64 / 1e6, prefill as f64 / 1e6, decode as f64 / 1e6)
}

/// Acceptance microcheck: with the fault plane disabled (the production
/// default), a `fault::point!` site must cost one relaxed atomic load —
/// single-digit nanoseconds, never a lock or a hash lookup — and must
/// never fire. Runs before the bench proper so a regression fails fast,
/// in CI's bench-smoke lane.
fn fault_plane_disabled_microcheck() {
    lords::fault::reset();
    assert!(!lords::fault::enabled(), "fault plane must start disabled");
    const N: u64 = 10_000_000;
    let mut fired = 0u64;
    let start = std::time::Instant::now();
    for i in 0..N {
        let hit = lords::fault::point!("bench.noop");
        if std::hint::black_box(hit).is_some() {
            fired += 1;
        }
        std::hint::black_box(i);
    }
    let ns_per_call = start.elapsed().as_nanos() as f64 / N as f64;
    assert_eq!(fired, 0, "disabled plane must never fire");
    assert!(
        ns_per_call < 50.0,
        "disabled fault site costs {ns_per_call:.2} ns/call — that is not one relaxed load"
    );
    eprintln!("[serve_online] disabled fault site: {ns_per_call:.3} ns/call over {N} calls");
}

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(7);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner(
        "Serve online",
        "open-loop streaming latency (TTFT/ITL/queue percentiles) through submit/step",
    );
    fault_plane_disabled_microcheck();

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let n_requests = if full { 32 } else { 12 };
    let max_new = if full { 24 } else { 12 };
    let prompt_len = cfg.max_seq / 4;
    let mut model = tb.model.clone();
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 30, ..Default::default() },
        false,
    );

    let mut t = lords::bench::TableBuilder::new(
        "Serve online — open-loop latency percentiles (native engine, fused LoRDS base)",
    )
    .headers(&[
        "KV",
        "Load",
        "Rate req/s",
        "Done",
        "Total tok/s",
        "TTFT p50/p99 ms",
        "ITL p50/p99 ms",
        "Queue p50/p99 ms",
        "Spans adm/pre/dec ms",
    ]);

    let mut points: Vec<Point> = Vec::new();
    for bits in [KvBits::F32, KvBits::Int8, KvBits::Int4] {
        // closed-loop calibration: the format's peak request rate
        let kv = KvQuantCfg::with_bits(bits);
        let serve = ServeCfg { kv_bits: bits.as_u32(), ..Default::default() };
        let mut server =
            Server::new(NativeEngine::with_kv(model.clone(), bits.name(), kv), serve).unwrap();
        let closed = server
            .run_trace(requests(n_requests, prompt_len, max_new, cfg.vocab))
            .unwrap();
        let peak_rps = closed.metrics.completed as f64 / closed.metrics.wall_secs.max(1e-9);
        eprintln!("[serve_online] {}: peak {:.1} req/s closed-loop", bits.name(), peak_rps);

        for rate_frac in [0.5, 0.9] {
            let rate_rps = (peak_rps * rate_frac).max(1.0);
            // record tracing spans for this run only: clear the drain
            // cursor first, then disable before draining so the totals
            // cover exactly the open-loop window
            lords::obs::trace::drain();
            lords::obs::trace::set_enabled(true);
            let report = run_open_loop(
                &mut server,
                requests(n_requests, prompt_len, max_new, cfg.vocab),
                rate_rps,
                11,
            )
            .unwrap();
            lords::obs::trace::set_enabled(false);
            let spans = lords::obs::trace::drain();
            let (span_admit_ms, span_prefill_ms, span_decode_ms) = tick_phase_ms(&spans);
            let m = &report.metrics;
            let p = Point {
                kv_bits: bits.as_u32(),
                rate_frac,
                rate_rps,
                completed: m.completed,
                total_tps: m.total_tps(),
                ttft_p50_ms: m.ttft.p50() * 1e3,
                ttft_p99_ms: m.ttft.p99() * 1e3,
                itl_p50_ms: m.itl.p50() * 1e3,
                itl_p99_ms: m.itl.p99() * 1e3,
                queue_p50_ms: m.queue_wait.p50() * 1e3,
                queue_p99_ms: m.queue_wait.p99() * 1e3,
                span_admit_ms,
                span_prefill_ms,
                span_decode_ms,
            };
            eprintln!(
                "[serve_online] {} @ {:.0}% load: ttft p99 {:.2} ms, itl p99 {:.2} ms",
                bits.name(),
                rate_frac * 100.0,
                p.ttft_p99_ms,
                p.itl_p99_ms
            );
            t.row(vec![
                bits.name().into(),
                format!("{:.0}%", rate_frac * 100.0),
                format!("{rate_rps:.1}"),
                p.completed.to_string(),
                format!("{:.1}", p.total_tps),
                format!("{:.2}/{:.2}", p.ttft_p50_ms, p.ttft_p99_ms),
                format!("{:.2}/{:.2}", p.itl_p50_ms, p.itl_p99_ms),
                format!("{:.2}/{:.2}", p.queue_p50_ms, p.queue_p99_ms),
                format!(
                    "{:.1}/{:.1}/{:.1}",
                    p.span_admit_ms, p.span_prefill_ms, p.span_decode_ms
                ),
            ]);
            points.push(p);
        }
    }
    t.print();
    println!(
        "\n(shape check: at 50% load queue-wait ≈ 0 and ITL tracks the decode step; \
         at 90% load TTFT p99 grows — later for int8/int4, whose budgets admit more \
         concurrent sequences)"
    );
    write_json(&points, full);
}

fn write_json(points: &[Point], full: bool) {
    let path = std::env::var("LORDS_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_online.json").to_string()
    });
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"serve_online\",\n");
    s.push_str("  \"unit\": \"milliseconds_and_tokens_per_second\",\n");
    s.push_str(&format!("  \"full_mode\": {full},\n"));
    s.push_str(&format!("  \"threads\": {},\n", lords::util::ThreadPool::global().size()));
    s.push_str("  \"measured\": true,\n");
    s.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kv_bits\": {}, \"rate_frac\": {:.2}, \"rate_rps\": {:.2}, \
             \"completed\": {}, \"total_tps\": {:.2}, \"ttft_p50_ms\": {:.3}, \
             \"ttft_p99_ms\": {:.3}, \"itl_p50_ms\": {:.3}, \"itl_p99_ms\": {:.3}, \
             \"queue_p50_ms\": {:.3}, \"queue_p99_ms\": {:.3}, \
             \"span_admit_ms\": {:.3}, \"span_prefill_ms\": {:.3}, \
             \"span_decode_ms\": {:.3}}}{}\n",
            p.kv_bits,
            p.rate_frac,
            p.rate_rps,
            p.completed,
            p.total_tps,
            p.ttft_p50_ms,
            p.ttft_p99_ms,
            p.itl_p50_ms,
            p.itl_p99_ms,
            p.queue_p50_ms,
            p.queue_p99_ms,
            p.span_admit_ms,
            p.span_prefill_ms,
            p.span_decode_ms,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[serve_online] wrote baseline {path}"),
        Err(e) => eprintln!("[serve_online] could not write {path}: {e}"),
    }
}
