//! Table 1 — PTQ comparison: NF4 / GPTQ / AWQ / LoftQ / LoRDS across the
//! model zoo at (equivalent) block sizes 64 and 128 (the paper's 128/256,
//! scaled to our matrix sizes), reporting Wiki/PTB perplexity and the
//! 7-task zero-shot average.
//!
//! Expected shape (paper): LoRDS leads the average at strict parameter
//! parity; LoftQ is competitive but uses a much larger float budget.
//! `FULL=1 cargo bench --bench table1_ptq` runs the full zoo.

use lords::bench::table::f2;
use lords::bench::TableBuilder;
use lords::config::{QuantCfg, QuantMethod};
use lords::report::methods::{quantize_model, CalibSet};
use lords::report::testbed::{eval_model, full_mode, model_zoo, Testbed};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 1", "PTQ: perplexity + zero-shot accuracy");

    let full = full_mode();
    let zoo = model_zoo();
    let models: Vec<_> = if full { zoo } else { zoo.into_iter().take(1).collect() };
    let blocks: Vec<usize> = if full { vec![64, 128] } else { vec![64] };
    let pretrain = if full { 300 } else { 120 };
    let per_task = if full { 40 } else { 16 };
    let ppl_windows = if full { 24 } else { 8 };
    let methods = [
        QuantMethod::Nf4Blockwise,
        QuantMethod::Gptq,
        QuantMethod::Awq,
        QuantMethod::LoftQ,
        QuantMethod::Lords,
    ];

    // Two regimes: nf4 (the paper's bit width — near-lossless at our testbed
    // scale, as 4-bit is for 8B models) and nf3, where our smaller matrices
    // reach the same *relative damage level* the paper's 4-bit tables show,
    // so the method ordering becomes visible. See EXPERIMENTS.md §T1.
    let codebooks: Vec<&str> = if full { vec!["nf4", "nf3"] } else { vec!["nf3"] };

    let mut tables = Vec::new();
    for (name, cfg) in &models {
        let tb = Testbed::build(name, cfg, pretrain, 0);
        let fp = eval_model(&tb.model, &tb, ppl_windows, per_task);
        for &block in &blocks {
            for &cbname in &codebooks {
            let mut t = TableBuilder::new(&format!("Table 1 — {name}, block {block}, {cbname}"))
                .headers(&["Method", "Wiki ↓", "PTB ↓", "Avg ↑", "#Float"]);
            t.row(vec![
                "fp32 (ref)".into(),
                fp.wiki.display(),
                fp.ptb.display(),
                f2(fp.avg),
                "-".into(),
            ]);
            for method in methods {
                let qcfg = QuantCfg {
                    method,
                    block,
                    codebook: cbname.into(),
                    refine_steps: if full { 300 } else { 80 },
                    adapter_rank: 16,
                    ..Default::default()
                };
                let calib = CalibSet::synthetic(&[cfg.d_model, cfg.d_ff], 128, 7);
                let mut model = tb.model.clone();
                let (_, secs) =
                    lords::util::stats::timed(|| quantize_model(&mut model, &qcfg, Some(&calib), 0));
                let e = eval_model(&model, &tb, ppl_windows, per_task);
                eprintln!(
                    "[table1] {name} b{block} {:<6} quantized in {secs:5.1}s  wiki {:>8} avg {:.2}",
                    method.name(),
                    e.wiki.display(),
                    e.avg
                );
                t.row(vec![
                    method.name().into(),
                    e.wiki.display(),
                    e.ptb.display(),
                    f2(e.avg),
                    lords::bench::table::thousands(model.float_params()),
                ]);
            }
            t.print();
            tables.push(t);
            }
        }
    }
    lords::bench::baseline::write_tables("table1_ptq", "BENCH_table1_ptq.json", full, &tables);
    println!("\n(shape check: LoRDS should lead Avg at parity budget; see EXPERIMENTS.md)");
}
