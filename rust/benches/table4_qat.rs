//! Table 4 — QAT: block-wise INT4 vs LoRDS, PTQ-only vs after QAT
//! fine-tuning (STE), on the pre-training corpus (the paper's SmolLM
//! protocol scaled down: cosine LR, 0.3 warmup ratio).
//!
//! Expected shape: QAT > PTQ for both structures, and LoRDS(-QAT) >
//! INT4(-QAT) — the continuous scaling manifold beats piecewise-constant
//! scales both before and after training.

use lords::bench::table::f2;
use lords::bench::TableBuilder;
use lords::config::TrainCfg;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{eval_model, full_mode, model_zoo, Testbed};
use lords::train::{NativeTrainer, TrainKind};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 4", "QAT: INT4 vs LoRDS, ±STE fine-tuning");

    let full = full_mode();
    let zoo = model_zoo();
    let models: Vec<_> = if full { zoo.into_iter().take(2).collect() } else { zoo.into_iter().take(1).collect() };
    let pretrain = if full { 300 } else { 120 };
    let qat_steps = if full { 120 } else { 40 };
    let block = 64;

    let mut tables = Vec::new();
    for (name, cfg) in &models {
        let tb = Testbed::build(name, cfg, pretrain, 0);
        let fp = eval_model(&tb.model, &tb, 8, 16);
        let mut t = TableBuilder::new(&format!("Table 4 — {name}, block {block}"))
            .headers(&["Method", "Wiki ↓", "PTB ↓", "Avg ↑"]);
        t.row(vec!["fp32 (ref)".into(), fp.wiki.display(), fp.ptb.display(), f2(fp.avg)]);

        let int4 = Codebook::int(3); // 3-bit regime (see EXPERIMENTS.md §T1)
        let nf4 = Codebook::normal_float(3);
        let refine = RefineCfg { steps: if full { 200 } else { 60 }, lr: 0.05, requant_every: 5 };
        let tcfg = TrainCfg {
            steps: qat_steps,
            batch: 8,
            seq: 64,
            peak_lr: 3e-4,
            warmup_ratio: 0.3,
            weight_decay: 0.0,
            seed: 0,
            log_every: 1000,
        };

        // PTQ rows
        let mut m_int4 = tb.model.clone();
        m_int4.quantize_blockwise(block, &int4);
        let e = eval_model(&m_int4, &tb, 8, 16);
        t.row(vec!["INT3".into(), e.wiki.display(), e.ptb.display(), f2(e.avg)]);

        let mut m_lords = tb.model.clone();
        m_lords.quantize_lords(block, &nf4, refine, false);
        let e = eval_model(&m_lords, &tb, 8, 16);
        t.row(vec!["LoRDS (nf3)".into(), e.wiki.display(), e.ptb.display(), f2(e.avg)]);

        // QAT rows: INT4-QAT = LoRDS machinery with the INT4 codebook and a
        // full-rank piecewise init is the blockwise STE baseline; here we
        // model it as QAT on blockwise-structured scales (rank = m/B init,
        // frozen A pattern) — implemented as LoRDS-QAT with int4 codebook.
        let mut m_int4_qat = tb.model.clone();
        m_int4_qat.quantize_lords(block, &int4, refine, true);
        let mut tr = NativeTrainer::new(tcfg.clone(), TrainKind::Qat);
        tr.run(&mut m_int4_qat, &tb.wiki);
        let e = eval_model(&m_int4_qat, &tb, 8, 16);
        eprintln!("[table4] {name} INT4-QAT wiki {}", e.wiki.display());
        t.row(vec!["INT3-QAT".into(), e.wiki.display(), e.ptb.display(), f2(e.avg)]);

        let mut m_lords_qat = tb.model.clone();
        m_lords_qat.quantize_lords(block, &nf4, refine, true);
        let mut tr = NativeTrainer::new(tcfg, TrainKind::Qat);
        tr.run(&mut m_lords_qat, &tb.wiki);
        let e = eval_model(&m_lords_qat, &tb, 8, 16);
        eprintln!("[table4] {name} LoRDS-QAT wiki {}", e.wiki.display());
        t.row(vec!["LoRDS-QAT (nf3)".into(), e.wiki.display(), e.ptb.display(), f2(e.avg)]);

        t.print();
        tables.push(t);
    }
    lords::bench::baseline::write_tables("table4_qat", "BENCH_table4_qat.json", full, &tables);
    println!("\n(shape check: *-QAT > PTQ, LoRDS-QAT > INT4-QAT)");
}
