//! Table 6 — end-to-end inference throughput through the coordinator:
//! fp32 / bnb-NF4 / QLoRA / LoRDS weight formats, prefill + decode + total
//! tokens/s, plus the serving weight footprint (packed codes + fp32
//! side-cars). The quantized formats all decode through the fused
//! bit-packed kernels (`lords::kernels`) — no dense Ŵ is ever built in
//! the engine's prefill/decode loop.
//!
//! Expected shape: LoRDS ≈ NF4 (rank-r scale reconstruction is the only
//! extra work) at ~1/7th the fp32 footprint, and both beat QLoRA (which
//! pays two extra adapter GEMMs per linear per token).

use lords::bench::TableBuilder;
use lords::config::ServeCfg;
use lords::coordinator::{NativeEngine, PjrtEngine, Request, Server};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::runtime::executor::Executor;
use lords::util::Rng;

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new))
        .collect()
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 6", "end-to-end serving throughput (batch, prefill+decode)");

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let n_requests = if full { 16 } else { 8 };
    let max_new = if full { 32 } else { 16 };
    let prompt_len = cfg.max_seq / 2;
    let cb = Codebook::normal_float(4);

    let mut t = TableBuilder::new("Table 6 — serving throughput (native engine, fused packed kernels)")
        .headers(&["Engine", "Method", "Weights MiB", "Prefill tok/s", "Decode tok/s", "Total tok/s"]);

    for format in ["fp", "nf4", "qlora", "lords"] {
        let mut model = tb.model.clone();
        match format {
            "fp" => {} // dense fp32 reference point
            "nf4" => model.quantize_blockwise(cfg.block, &cb),
            "qlora" => {
                model.quantize_qlora(cfg.block, cfg.qlora_rank, &cb, 0);
                // non-zero adapters (post-finetuning state = the paper's setting)
                let mut rng = Rng::new(7);
                for layer in model.layers.iter_mut() {
                    for (_, lw) in layer.linears_mut() {
                        if let lords::model::LinearWeight::Qlora(q) = lw {
                            rng.fill_normal(&mut q.lora_b.data, 0.0, 0.01);
                        }
                    }
                }
            }
            _ => model.quantize_lords(cfg.block, &cb, RefineCfg { steps: 30, ..Default::default() }, false),
        }
        let engine = NativeEngine::new(model, format);
        let mib = engine.weight_bytes() as f64 / (1024.0 * 1024.0);
        let mut server = Server::new(engine, ServeCfg::default()).unwrap();
        let report = server.run_trace(requests(n_requests, prompt_len, max_new, cfg.vocab, 1)).unwrap();
        let m = &report.metrics;
        eprintln!("[table6] native/{format}: total {:.1} tok/s ({mib:.2} MiB weights)", m.total_tps());
        t.row(vec![
            "native".into(),
            label(format),
            format!("{mib:.2}"),
            format!("{:.1}", m.prefill_tps()),
            format!("{:.1}", m.decode_tps()),
            format!("{:.1}", m.total_tps()),
        ]);
    }
    t.print();
    let mut tables = vec![t];

    // PJRT operating point (uses the AOT artifacts if present)
    match Executor::spawn("artifacts") {
        Ok(exec) => {
            let manifest = lords::runtime::Manifest::load("artifacts").unwrap();
            let mcfg = manifest.model.clone();
            let tbp = Testbed::build("llama3-mini", &mcfg, if full { 300 } else { 120 }, 0);
            let mut t2 = TableBuilder::new("Table 6 — serving throughput (PJRT engine)")
                .headers(&["Engine", "Method", "Prefill tok/s", "Decode tok/s", "Total tok/s"]);
            for format in ["nf4", "qlora", "lords"] {
                let mut model = tbp.model.clone();
                let cb = Codebook::from_levels(&manifest.lut_name, manifest.lut.clone());
                match format {
                    "nf4" => model.quantize_blockwise(mcfg.block, &cb),
                    "qlora" => model.quantize_qlora(mcfg.block, mcfg.qlora_rank, &cb, 0),
                    _ => model.quantize_lords(
                        mcfg.block,
                        &cb,
                        RefineCfg { steps: 30, ..Default::default() },
                        false,
                    ),
                }
                let art = manifest.artifact(&format!("{format}_prefill_b1")).unwrap();
                let params = lords::runtime::bridge::collect_params(&model, &art.inputs);
                let engine = PjrtEngine::new(exec.handle(), &manifest, format, params).unwrap();
                let plen = engine.prefill_seq;
                let mut server = Server::new(engine, ServeCfg::default()).unwrap();
                let reqs = requests(n_requests.min(8), plen, max_new, mcfg.vocab, 2);
                match server.run_trace(reqs) {
                    Ok(report) => {
                        let m = &report.metrics;
                        eprintln!("[table6] pjrt/{format}: total {:.1} tok/s", m.total_tps());
                        t2.row(vec![
                            "pjrt".into(),
                            label(format),
                            format!("{:.1}", m.prefill_tps()),
                            format!("{:.1}", m.decode_tps()),
                            format!("{:.1}", m.total_tps()),
                        ]);
                    }
                    Err(e) => eprintln!("[table6] pjrt/{format} failed: {e:#}"),
                }
            }
            t2.print();
            tables.push(t2);
        }
        Err(e) => eprintln!("[table6] PJRT engine skipped ({e})  — run `make artifacts`"),
    }
    lords::bench::baseline::write_tables(
        "table6_throughput",
        "BENCH_table6_throughput.json",
        full,
        &tables,
    );
    println!("\n(shape check: LoRDS ≈ NF4 > QLoRA on decode and total)");
}

fn label(f: &str) -> String {
    match f {
        "fp" => "fp32".into(),
        "nf4" => "bnb NF4".into(),
        "qlora" => "QLoRA".into(),
        _ => "LoRDS".into(),
    }
}
