//! Table 3 — ultra-low-bit quantization on the Llama-like testbed:
//! 3 / 2.5 / 2.25-bit mixed-precision schedules (NF4 on a layer prefix,
//! NF2 on the rest), comparing plain NormalFloat, LoftQ (rank-16 adapters),
//! and LoRDS, with #Float accounting and divergence ("N.A.") detection.
//!
//! Expected shape: block-wise NF collapses (N.A. or huge PPL), LoftQ decays
//! fast, LoRDS degrades gracefully and leads at every bit width.

use lords::bench::table::f2;
use lords::bench::table::thousands;
use lords::bench::TableBuilder;
use lords::model::LinearWeight;
use lords::quant::baselines::loftq_quantize;
use lords::quant::lords::RefineCfg;
use lords::quant::mixed::MixedSchedule;
use lords::quant::{BlockwiseQuant, LordsQuant};
use lords::report::testbed::{eval_model, full_mode, model_zoo, Testbed};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 3", "ultra-low-bit (mixed NF4/NF2) robustness");

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let pretrain = if full { 300 } else { 120 };
    let tb = Testbed::build(name, &cfg, pretrain, 0);
    let block = 64;
    let refine = RefineCfg { steps: if full { 300 } else { 120 }, lr: 0.05, requant_every: 5 };
    let bits_list: Vec<f32> = if full { vec![3.0, 2.5, 2.25, 2.0] } else { vec![3.0, 2.25] };

    let mut t = TableBuilder::new("Table 3 — reduced bit-widths (Llama-like, block 64)")
        .headers(&["Bit", "Method", "#Float", "Wiki ↓", "PTB ↓", "Avg ↑"]);

    for &bits in &bits_list {
        let sched = MixedSchedule::for_bits(bits, cfg.n_layers);
        for method in ["NormalFloat", "LoftQ", "LoRDS"] {
            let mut model = tb.model.clone();
            match method {
                "NormalFloat" => model.map_linears_by_layer(|li, w| {
                    LinearWeight::Blockwise(BlockwiseQuant::quantize(
                        w,
                        block,
                        &sched.codebook_for_layer(li),
                    ))
                }),
                "LoftQ" => model.map_linears_by_layer(|li, w| {
                    let a = loftq_quantize(w, block, 16, 5, &sched.codebook_for_layer(li));
                    LinearWeight::Qlora(lords::quant::baselines::QloraLinear {
                        base: a.base,
                        lora_a: a.lora_a,
                        lora_b: a.lora_b,
                        scaling: 1.0,
                    })
                }),
                _ => model.map_linears_by_layer(|li, w| {
                    let (q, _) = LordsQuant::quantize(w, block, &sched.codebook_for_layer(li), refine);
                    LinearWeight::Lords { q, shadow_w: None }
                }),
            }
            let e = eval_model(&model, &tb, 8, if full { 40 } else { 16 });
            eprintln!(
                "[table3] {bits}-bit {method:<11} wiki {:>9} avg {:.2}",
                e.wiki.display(),
                e.avg
            );
            t.row(vec![
                sched.bits_label.clone(),
                method.into(),
                thousands(model.float_params()),
                e.wiki.display(),
                e.ptb.display(),
                f2(e.avg),
            ]);
        }
    }
    t.print();
    lords::bench::baseline::write_tables("table3_lowbit", "BENCH_table3_lowbit.json", full, &[t]);
    println!("\n(shape check: NF collapses, LoRDS stays usable at every bit width)");
}
