//! KV-cache bench — decode throughput, KV memory, and max concurrent
//! sequences at a fixed pool byte budget, for dense f32 vs 8-bit vs 4-bit
//! packed KV (rank-r low-rank scales per block, `kvquant`).
//!
//! Per format the serve trace reports prefill/decode/total tokens/s and
//! the pool's peak sealed-storage bytes; a fixed 64 MiB budget is then
//! sized per format to report how many worst-case (`max_seq`) sequences
//! it admits — the lever that multiplies serving concurrency. The 8-bit
//! run also checks token-parity against the dense trace.
//!
//! Expected shape: 8/4-bit decode within a modest factor of dense (the
//! fused packed attention pays one dequant sweep per cached row), with
//! ≥ 3.5x KV-bytes reduction and ≥ 2x admitted sequences at 4-bit.
//!
//! Results are written to `BENCH_kvcache.json` (override with
//! `LORDS_BENCH_JSON=path`).

use lords::bench::TableBuilder;
use lords::config::ServeCfg;
use lords::coordinator::{NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvPool, KvQuantCfg};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::util::Rng;

const BUDGET_MIB: usize = 64;

struct Point {
    kv_bits: u32,
    block_bytes: usize,
    kv_peak_mib: f64,
    prefill_tps: f64,
    decode_tps: f64,
    total_tps: f64,
    max_concurrent_at_budget: usize,
    token_match_vs_dense: bool,
}

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = Rng::new(7);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner(
        "KV cache",
        "block-pooled packed KV: decode throughput + KV MiB + concurrency at a fixed budget",
    );

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let n_requests = if full { 16 } else { 8 };
    let max_new = if full { 32 } else { 16 };
    let prompt_len = cfg.max_seq / 2;
    let mut model = tb.model.clone();
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 30, ..Default::default() },
        false,
    );

    let mut t = TableBuilder::new(&format!(
        "KV cache — dense vs packed blocks (native engine; {BUDGET_MIB} MiB budget column)"
    ))
    .headers(&[
        "KV",
        "B/block",
        "Peak KV MiB",
        "Prefill tok/s",
        "Decode tok/s",
        "Total tok/s",
        "Max seqs @ budget",
        "Tokens = dense",
    ]);

    let mut points: Vec<Point> = Vec::new();
    let mut dense_tokens: Vec<Vec<usize>> = Vec::new();
    for bits in [KvBits::F32, KvBits::Int8, KvBits::Int4] {
        let kv = KvQuantCfg::with_bits(bits);
        let engine = NativeEngine::with_kv(model.clone(), bits.name(), kv);
        let serve = ServeCfg { kv_bits: bits.as_u32(), ..Default::default() };
        let mut server = Server::new(engine, serve).unwrap();
        let report = server.run_trace(requests(n_requests, prompt_len, max_new, cfg.vocab)).unwrap();
        let m = &report.metrics;
        let pool = server.engine.kv_pool();
        let tokens: Vec<Vec<usize>> = report.responses.iter().map(|r| r.tokens.clone()).collect();
        let token_match = if bits == KvBits::F32 {
            dense_tokens = tokens;
            true
        } else {
            tokens == dense_tokens
        };
        // concurrency at the fixed budget, independent of the serve above
        let sized = KvPool::with_byte_budget(
            kv,
            cfg.n_layers,
            cfg.d_model,
            BUDGET_MIB << 20,
            cfg.max_seq,
        );
        let p = Point {
            kv_bits: bits.as_u32(),
            block_bytes: pool.block_bytes(),
            kv_peak_mib: pool.peak_bytes() as f64 / (1024.0 * 1024.0),
            prefill_tps: m.prefill_tps(),
            decode_tps: m.decode_tps(),
            total_tps: m.total_tps(),
            max_concurrent_at_budget: sized.max_concurrent_full_seqs(cfg.max_seq),
            token_match_vs_dense: token_match,
        };
        eprintln!(
            "[kvcache] {}: decode {:.1} tok/s, peak KV {:.2} MiB, {} seqs @ {BUDGET_MIB} MiB{}",
            bits.name(),
            p.decode_tps,
            p.kv_peak_mib,
            p.max_concurrent_at_budget,
            if token_match { "" } else { "  [token mismatch]" }
        );
        t.row(vec![
            bits.name().into(),
            p.block_bytes.to_string(),
            format!("{:.2}", p.kv_peak_mib),
            format!("{:.1}", p.prefill_tps),
            format!("{:.1}", p.decode_tps),
            format!("{:.1}", p.total_tps),
            p.max_concurrent_at_budget.to_string(),
            token_match.to_string(),
        ]);
        points.push(p);
    }
    t.print();

    let dense = &points[0];
    println!(
        "\n(acceptance: 4-bit KV bytes {:.2}x smaller, {:.2}x max sequences at {BUDGET_MIB} MiB; \
         8-bit token-identical: {})",
        dense.block_bytes as f64 / points[2].block_bytes as f64,
        points[2].max_concurrent_at_budget as f64 / dense.max_concurrent_at_budget.max(1) as f64,
        points[1].token_match_vs_dense
    );
    write_json(&points, full);
}

fn write_json(points: &[Point], full: bool) {
    let path = std::env::var("LORDS_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kvcache.json").to_string()
    });
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"kvcache_bench\",\n");
    s.push_str("  \"unit\": \"tokens_per_second_and_bytes\",\n");
    s.push_str(&format!("  \"full_mode\": {full},\n"));
    s.push_str(&format!("  \"threads\": {},\n", lords::util::ThreadPool::global().size()));
    s.push_str(&format!("  \"budget_mib\": {BUDGET_MIB},\n"));
    s.push_str("  \"measured\": true,\n");
    s.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kv_bits\": {}, \"block_bytes\": {}, \"kv_peak_mib\": {:.4}, \
             \"prefill_tps\": {:.2}, \"decode_tps\": {:.2}, \"total_tps\": {:.2}, \
             \"max_concurrent_at_budget\": {}, \"token_match_vs_dense\": {}}}{}\n",
            p.kv_bits,
            p.block_bytes,
            p.kv_peak_mib,
            p.prefill_tps,
            p.decode_tps,
            p.total_tps,
            p.max_concurrent_at_budget,
            p.token_match_vs_dense,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[kvcache] wrote baseline {path}"),
        Err(e) => eprintln!("[kvcache] could not write {path}: {e}"),
    }
}
