//! Table 9 (Appendix B) — error reduction ratio at ultra-low bit widths
//! (3 / 2.5 / 2.25 / 2-bit mixed NF4/NF2 schedules), per module, for
//! NF4-baseline / LoftQ / QPiSSA / LoRDS.
//!
//! Expected shape: LoRDS's advantage *grows* as bits shrink (paper: ~3×
//! the adapter methods' ratio, rising from ≈32% at 3-bit to ≈36% at 2-bit).

use lords::bench::table::f1;
use lords::bench::TableBuilder;
use lords::quant::baselines::{loftq_quantize, qpissa_quantize};
use lords::quant::error::reduction_ratio_vs;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::testbed::{full_mode, module_suite};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 9", "reduction ratio at low bit-widths");

    let full = full_mode();
    let scale = if full { 8 } else { 16 };
    let block = 64;
    let refine = RefineCfg { steps: if full { 300 } else { 120 }, lr: 0.05, requant_every: 5 };
    let suite = module_suite(scale, 0);
    let adapter_rank = (32 / scale).max(2); // scaled with module size, as in table 8
    // per-matrix mixed precision: bits b ⇒ NF4 with prob (b-2)/2 else NF2;
    // at matrix granularity we interpolate by fraction of *modules* in NF4,
    // mirroring the paper's layer-prefix rule.
    let bits_list: Vec<f32> = if full { vec![3.0, 2.5, 2.25, 2.0] } else { vec![3.0, 2.0] };

    let mut tables = Vec::new();
    for &bits in &bits_list {
        let nf4_frac = ((bits - 2.0) / 2.0).clamp(0.0, 1.0);
        let n_nf4 = (nf4_frac * suite.len() as f32).round() as usize;
        let cb_for = |i: usize| {
            if i < n_nf4 {
                Codebook::normal_float(4)
            } else {
                Codebook::normal_float(2)
            }
        };
        let mut t = TableBuilder::new(&format!("Table 9 — {bits}-bit, block {block}"))
            .headers(&["Method", "Q", "K", "V", "O", "Gate", "Up", "Down", "AVG ↑"]);

        // NF baseline at these bits (the denominator uses NF at the same bits)
        let baselines: Vec<_> = suite
            .iter()
            .enumerate()
            .map(|(i, (_, w))| BlockwiseQuant::quantize(w, block, &cb_for(i)).dequantize())
            .collect();

        for method in ["NF", "LoftQ", "QPiSSA", "LoRDS"] {
            let mut cells = Vec::new();
            let mut avg = 0.0;
            for (i, (shape, w)) in suite.iter().enumerate() {
                let cb = cb_for(i);
                let w_hat = match method {
                    "NF" => baselines[i].clone(),
                    "LoftQ" => loftq_quantize(w, block, adapter_rank, 5, &cb).dequantize(),
                    "QPiSSA" => qpissa_quantize(w, block, adapter_rank, 5, &cb).dequantize(),
                    _ => LordsQuant::quantize(w, block, &cb, refine).0.dequantize(),
                };
                let ratio = reduction_ratio_vs(w, &w_hat, &baselines[i]);
                avg += ratio;
                cells.push((shape.name, ratio));
            }
            avg /= suite.len() as f32;
            eprintln!("[table9] {bits}-bit {method:<7} avg {avg:.1}%");
            let mut row = vec![method.to_string()];
            row.extend(cells.iter().map(|(_, r)| f1(*r)));
            row.push(f1(avg));
            t.row(row);
        }
        t.print();
        tables.push(t);
    }
    lords::bench::baseline::write_tables(
        "table9_lowbit_ratio",
        "BENCH_table9_lowbit_ratio.json",
        full,
        &tables,
    );
    println!("\n(shape check: LoRDS ratio ≈ 3× the adapter methods and grows as bits shrink)");
}
