//! Batched decode tick bench — the looped per-sequence decode
//! (`NativeEngine::decode_reference`) vs the batched tenant-grouped tick
//! (`Engine::decode`) at B ∈ {1, 4, 16, 64} running sequences, with 1 and
//! 4 tenants.
//!
//! The quantity under test is per-tick packed-weight traffic: the looped
//! path streams + dequantizes + scale-reconstructs every weight tile once
//! **per sequence** (`B × bytes(W)` per tick), the batched tick once **per
//! tenant-group** (`groups × bytes(W)`). The weight-stream columns are
//! analytic (exact from `Model::weight_bytes`); tok/s is measured. Both
//! paths are token-identical (gated by `tests/decode_batch.rs`), so this
//! is a pure throughput comparison.
//!
//! Expected shape: batched decode approaches `B / groups ×` less weight
//! traffic — ≥ 4x analytic reduction at B = 16 single-tenant (it is 16x) —
//! with measured speedups tracking it at the memory-bound sizes.
//!
//! Results are written to `BENCH_decode_batch.json` (override with
//! `LORDS_BENCH_JSON=path`).

use lords::adapters::AdapterFactors;
use lords::bench::harness::time_once;
use lords::bench::TableBuilder;
use lords::coordinator::engine::SeqState;
use lords::coordinator::{Engine, NativeEngine, Request};
use lords::model::Model;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::util::Rng;

const PROMPT_LEN: usize = 16;

struct Point {
    batch: usize,
    tenants: usize,
    groups: usize,
    looped_tps: f64,
    batched_tps: f64,
    speedup: f64,
    weight_mib: f64,
    looped_stream_mib_per_tick: f64,
    batched_stream_mib_per_tick: f64,
    stream_ratio: f64,
}

fn build_engine(model: &Model, adapters: &[AdapterFactors], label: &str) -> NativeEngine {
    let mut engine = NativeEngine::new(model.clone(), label);
    for (i, a) in adapters.iter().enumerate() {
        engine.register_adapter(&format!("t{i}"), a.clone()).unwrap();
    }
    engine
}

/// Prefill `b` sequences round-robined over `base + adapters` tenants.
fn prefill_batch(
    engine: &mut NativeEngine,
    b: usize,
    tenants: usize,
    max_seq: usize,
    vocab: usize,
    ticks: usize,
) -> Vec<SeqState> {
    let mut rng = Rng::new(17);
    let mut seqs: Vec<SeqState> = (0..b as u64)
        .map(|id| {
            let prompt: Vec<usize> = (0..PROMPT_LEN).map(|_| rng.below(vocab)).collect();
            let tenant = match id as usize % tenants {
                0 => "base".to_string(),
                t => format!("t{}", t - 1),
            };
            SeqState::admit(&Request::new(id, prompt, ticks).with_adapter(&tenant), max_seq)
        })
        .collect();
    engine.prefill(&mut seqs).unwrap();
    seqs
}

/// Advance `ticks` decode ticks (greedy sampling), timing only the engine
/// calls. `batched = false` drives the per-sequence reference loop.
fn run_ticks(
    engine: &mut NativeEngine,
    seqs: &mut Vec<SeqState>,
    ticks: usize,
    batched: bool,
) -> f64 {
    let mut secs = 0.0;
    for _ in 0..ticks {
        for s in seqs.iter_mut() {
            let tok = s.next_token();
            s.tokens.push(tok);
        }
        let (res, dt) = time_once(|| {
            if batched {
                engine.decode(seqs)
            } else {
                engine.decode_reference(seqs)
            }
        });
        res.unwrap();
        secs += dt.as_secs_f64();
    }
    secs
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner(
        "decode batch",
        "looped per-sequence decode vs batched tenant-grouped tick (weight streams per tick)",
    );

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let ticks = if full { 32 } else { 8 };
    let mut model = tb.model.clone();
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: 30, ..Default::default() },
        false,
    );
    let weight_bytes = model.weight_bytes();
    let weight_mib = weight_bytes as f64 / (1024.0 * 1024.0);
    let base_factors = AdapterFactors::from_model(&model);
    let mut arng = Rng::new(3);
    let adapters: Vec<AdapterFactors> =
        (0..3).map(|_| base_factors.perturbed(0.05, &mut arng)).collect();

    let mut t = TableBuilder::new(&format!(
        "Batched decode tick — {name}, 4-bit LoRDS, packed weights {weight_mib:.2} MiB"
    ))
    .headers(&[
        "B",
        "Tenants",
        "Groups",
        "Looped tok/s",
        "Batched tok/s",
        "Speedup",
        "W-stream looped MiB/tick",
        "W-stream batched MiB/tick",
        "Stream ratio",
    ]);

    let mut points: Vec<Point> = Vec::new();
    for &b in &[1usize, 4, 16, 64] {
        for &tenants in &[1usize, 4] {
            if tenants > b {
                continue;
            }
            let groups = tenants.min(b);
            // fresh engine per leg so each path decodes the same positions
            let mut eng = build_engine(&model, &adapters[..tenants - 1], "looped");
            let mut seqs = prefill_batch(&mut eng, b, tenants, cfg.max_seq, cfg.vocab, ticks);
            let looped_secs = run_ticks(&mut eng, &mut seqs, ticks, false);

            let mut eng = build_engine(&model, &adapters[..tenants - 1], "batched");
            let mut seqs = prefill_batch(&mut eng, b, tenants, cfg.max_seq, cfg.vocab, ticks);
            let batched_secs = run_ticks(&mut eng, &mut seqs, ticks, true);
            assert_eq!(eng.last_decode_groups(), groups, "tick must form {groups} groups");

            let tokens = (b * ticks) as f64;
            let p = Point {
                batch: b,
                tenants,
                groups,
                looped_tps: tokens / looped_secs.max(1e-12),
                batched_tps: tokens / batched_secs.max(1e-12),
                speedup: looped_secs / batched_secs.max(1e-12),
                weight_mib,
                looped_stream_mib_per_tick: b as f64 * weight_mib,
                batched_stream_mib_per_tick: groups as f64 * weight_mib,
                stream_ratio: b as f64 / groups as f64,
            };
            eprintln!(
                "[decode_batch] B={b} tenants={tenants}: looped {:.1} tok/s, batched {:.1} tok/s \
                 ({:.2}x), weight stream {:.1} → {:.1} MiB/tick ({:.0}x)",
                p.looped_tps,
                p.batched_tps,
                p.speedup,
                p.looped_stream_mib_per_tick,
                p.batched_stream_mib_per_tick,
                p.stream_ratio,
            );
            t.row(vec![
                b.to_string(),
                tenants.to_string(),
                groups.to_string(),
                format!("{:.1}", p.looped_tps),
                format!("{:.1}", p.batched_tps),
                format!("{:.2}", p.speedup),
                format!("{:.1}", p.looped_stream_mib_per_tick),
                format!("{:.1}", p.batched_stream_mib_per_tick),
                format!("{:.0}x", p.stream_ratio),
            ]);
            points.push(p);
        }
    }
    t.print();

    let b16 = points
        .iter()
        .find(|p| p.batch == 16 && p.tenants == 1)
        .expect("B=16 single-tenant point");
    println!(
        "\n(acceptance: per-tick packed-weight bytes at B=16 single tenant drop {:.0}x — \
         {:.1} MiB → {:.1} MiB; ≥ 4x required)",
        b16.stream_ratio, b16.looped_stream_mib_per_tick, b16.batched_stream_mib_per_tick
    );
    write_json(&points, full);
}

fn write_json(points: &[Point], full: bool) {
    let path = std::env::var("LORDS_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode_batch.json").to_string()
    });
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"decode_batch\",\n");
    s.push_str("  \"unit\": \"tokens_per_second_and_weight_stream_mib_per_tick\",\n");
    s.push_str(&format!("  \"full_mode\": {full},\n"));
    s.push_str(&format!("  \"threads\": {},\n", lords::util::ThreadPool::global().size()));
    s.push_str("  \"measured\": true,\n");
    s.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"batch\": {}, \"tenants\": {}, \"groups\": {}, \"looped_tps\": {:.2}, \
             \"batched_tps\": {:.2}, \"speedup\": {:.3}, \"weight_mib\": {:.4}, \
             \"looped_stream_mib_per_tick\": {:.4}, \"batched_stream_mib_per_tick\": {:.4}, \
             \"stream_ratio\": {:.2}}}{}\n",
            p.batch,
            p.tenants,
            p.groups,
            p.looped_tps,
            p.batched_tps,
            p.speedup,
            p.weight_mib,
            p.looped_stream_mib_per_tick,
            p.batched_stream_mib_per_tick,
            p.stream_ratio,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[decode_batch] wrote baseline {path}"),
        Err(e) => eprintln!("[decode_batch] could not write {path}: {e}"),
    }
}
