//! Table 8 (Appendix B) — quantization-error reduction ratio vs the NF4
//! baseline, per module (Q/K/V/O/Gate/Up/Down at the paper's aspect
//! ratios), for NF4 / LoftQ / QPiSSA / LoRDS / LoRDS† (parameter-aligned
//! with the adapter budget).
//!
//! Expected shape: LoRDS ≥ LoftQ/QPiSSA at a *smaller* float budget, and
//! LoRDS† pulls far ahead once budgets are aligned.

use lords::bench::table::f1;
use lords::bench::TableBuilder;
use lords::config::{QuantCfg, QuantMethod};
use lords::quant::error::reduction_ratio_vs;
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::methods::apply_method;
use lords::report::testbed::{full_mode, module_suite};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 8", "error reduction ratio per module (vs NF4)");

    let full = full_mode();
    let scale = if full { 8 } else { 16 }; // 4096→512 or →256
    let blocks: Vec<usize> = if full { vec![64, 128] } else { vec![64] };
    let refine = if full { 300 } else { 120 };
    // adapter rank scaled with the modules (paper: 16 at 4096-dim; same
    // budget fraction here), so the #Float comparison stays fair
    let adapter_rank = (32 / scale).max(2);
    let suite = module_suite(scale, 0);
    let cb = Codebook::normal_float(4);

    let mut tables = Vec::new();
    for &block in &blocks {
        let mut t = TableBuilder::new(&format!(
            "Table 8 — reduction ratio %, Llama-like modules at 1/{scale} scale, block {block}"
        ))
        .headers(&["Method", "#Float", "Q", "K", "V", "O", "Gate", "Up", "Down", "AVG ↑"]);

        let specs = [
            (QuantMethod::Nf4Blockwise, false),
            (QuantMethod::LoftQ, false),
            (QuantMethod::QPissa, false),
            (QuantMethod::Lords, false),
            (QuantMethod::Lords, true), // LoRDS†
        ];
        for (method, aligned) in specs {
            let mut cells = Vec::new();
            let mut avg = 0.0f32;
            let mut floats = 0usize;
            for (shape, w) in &suite {
                let nf4 = BlockwiseQuant::quantize(w, block, &cb);
                let base = nf4.dequantize();
                let cfg = QuantCfg {
                    method,
                    block,
                    refine_steps: refine,
                    adapter_rank,
                    parity_with_adapter: aligned,
                    ..Default::default()
                };
                let r = apply_method(w, &cfg, None, 0);
                let ratio = reduction_ratio_vs(w, &r.w_hat, &base);
                floats += r.float_params;
                avg += ratio;
                cells.push((shape.name, ratio));
            }
            avg /= suite.len() as f32;
            let label = if aligned { "LoRDS†".to_string() } else { method.name().to_string() };
            eprintln!("[table8] b{block} {label:<7} avg ratio {avg:.1}%");
            let mut row = vec![label, lords::bench::table::thousands(floats)];
            row.extend(cells.iter().map(|(_, r)| f1(*r)));
            row.push(f1(avg));
            t.row(row);
        }
        t.print();
        tables.push(t);
    }
    lords::bench::baseline::write_tables(
        "table8_error_ratio",
        "BENCH_table8_error_ratio.json",
        full,
        &tables,
    );
    println!("\n(shape check: LoRDS > LoftQ/QPiSSA at smaller #Float; LoRDS† > all)");
}
