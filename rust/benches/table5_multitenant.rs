//! Table 5b — multi-tenant adapter serving: one shared LoRDS packed base
//! hosting N hot-swappable scale adapters, versus the additive-adapter
//! deployment (QLoRA: one engine per tenant, two extra adapter GEMMs on
//! every forward).
//!
//! Reported per deployment: total weight bytes (the LoRDS base is counted
//! **once**, plus ~r·(n+m) floats per tenant; the QLoRA deployment
//! replicates its NF4 base per engine) and prefill/decode/total tokens/s
//! over the same mixed-tenant request trace.
//!
//! Expected shape: LoRDS serves N tenants at ≈ single-tenant throughput
//! (the adapter override swaps two small factor matrices per linear call —
//! no extra matmuls) and ≈ 1/N the weight bytes of per-tenant QLoRA
//! engines.
//!
//! Tenant adapters are synthetic PEFT deltas (deterministically perturbed
//! base factors): identical shapes and serving cost to trained adapters,
//! which is what a *serving* bench measures.

use lords::adapters::{AdapterFactors, AdapterRegistry};
use lords::bench::TableBuilder;
use lords::config::ServeCfg;
use lords::coordinator::metrics::ServeMetrics;
use lords::coordinator::{NativeEngine, Request, Server};
use lords::model::LinearWeight;
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::util::Rng;

fn requests(n: usize, prompt_len: usize, max_new: usize, vocab: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            Request::new(i as u64, (0..prompt_len).map(|_| rng.below(vocab)).collect(), max_new)
        })
        .collect()
}

fn row(t: &mut TableBuilder, label: &str, tenants: usize, bytes: usize, m: &ServeMetrics) {
    t.row(vec![
        label.into(),
        tenants.to_string(),
        format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
        format!("{:.1}", m.prefill_tps()),
        format!("{:.1}", m.decode_tps()),
        format!("{:.1}", m.total_tps()),
    ]);
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner(
        "Table 5b",
        "multi-tenant adapter serving: shared LoRDS base + N adapters vs N QLoRA engines",
    );

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 300 } else { 120 }, 0);
    let n_tenants = if full { 6 } else { 3 };
    let n_requests = if full { 24 } else { 12 };
    let max_new = if full { 32 } else { 16 };
    let prompt_len = cfg.max_seq / 2;
    let cb = Codebook::normal_float(4);
    let refine = RefineCfg { steps: 30, ..Default::default() };

    let mut t = TableBuilder::new(
        "Table 5b — multi-tenant serving (native engine, shared packed base)",
    )
    .headers(&["Deployment", "Tenants", "Weights MiB", "Prefill tok/s", "Decode tok/s", "Total tok/s"]);

    // ---------------- LoRDS: one base, N scale adapters, mixed batches
    let mut lords_model = tb.model.clone();
    lords_model.quantize_lords(cfg.block, &cb, refine, false);
    let base_factors = AdapterFactors::from_model(&lords_model);
    let mut engine = NativeEngine::with_registry(lords_model, "mt", AdapterRegistry::unbounded());
    let mut arng = Rng::new(41);
    let tenant_ids: Vec<String> = (0..n_tenants).map(|i| format!("tenant-{i}")).collect();
    for id in &tenant_ids {
        engine.register_adapter(id, base_factors.perturbed(0.05, &mut arng)).unwrap();
    }
    let bytes_lords = engine.weight_bytes(); // base once + all resident adapters
    let mut reqs = requests(n_requests, prompt_len, max_new, cfg.vocab, 1);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.adapter = tenant_ids[i % n_tenants].clone();
    }
    let mut server = Server::new(engine, ServeCfg::default()).unwrap();
    let report = server.run_trace(reqs).unwrap();
    eprintln!(
        "[table5b] lords 1-base-{n_tenants}-adapters: total {:.1} tok/s ({:.2} MiB)",
        report.metrics.total_tps(),
        bytes_lords as f64 / (1024.0 * 1024.0)
    );
    report.metrics.print_adapters();
    row(&mut t, "LoRDS shared base + adapters", n_tenants, bytes_lords, &report.metrics);

    // single-tenant LoRDS baseline (same engine shape, base tenant only) —
    // the "zero inference overhead" comparison point
    let mut base_model = tb.model.clone();
    base_model.quantize_lords(cfg.block, &cb, refine, false);
    let engine_base = NativeEngine::new(base_model, "single");
    let bytes_base = engine_base.weight_bytes();
    let mut server_base = Server::new(engine_base, ServeCfg::default()).unwrap();
    let report_base =
        server_base.run_trace(requests(n_requests, prompt_len, max_new, cfg.vocab, 1)).unwrap();
    row(&mut t, "LoRDS single tenant (base)", 1, bytes_base, &report_base.metrics);

    // ---------------- QLoRA: additive adapters need one engine per tenant
    let mut agg = ServeMetrics::default();
    let mut bytes_qlora = 0usize;
    for ti in 0..n_tenants {
        let mut qmodel = tb.model.clone();
        qmodel.quantize_qlora(cfg.block, cfg.qlora_rank, &cb, 0);
        // non-zero adapters = post-finetuning state, distinct per tenant
        let mut rng = Rng::new(100 + ti as u64);
        for layer in qmodel.layers.iter_mut() {
            for (_, lw) in layer.linears_mut() {
                if let LinearWeight::Qlora(q) = lw {
                    rng.fill_normal(&mut q.lora_b.data, 0.0, 0.01);
                }
            }
        }
        let engine = NativeEngine::new(qmodel, &format!("qlora-{ti}"));
        bytes_qlora += engine.weight_bytes(); // per-tenant base replica
        let mut server = Server::new(engine, ServeCfg::default()).unwrap();
        // this tenant's share of the same trace
        let share: Vec<Request> = requests(n_requests, prompt_len, max_new, cfg.vocab, 1)
            .into_iter()
            .enumerate()
            .filter(|(i, _)| i % n_tenants == ti)
            .map(|(_, r)| r)
            .collect();
        let rep = server.run_trace(share).unwrap();
        agg.prefill_tokens += rep.metrics.prefill_tokens;
        agg.decode_tokens += rep.metrics.decode_tokens;
        agg.prefill_secs += rep.metrics.prefill_secs;
        agg.decode_secs += rep.metrics.decode_secs;
        agg.wall_secs += rep.metrics.wall_secs;
        agg.completed += rep.metrics.completed;
    }
    eprintln!(
        "[table5b] qlora {n_tenants} engines: total {:.1} tok/s ({:.2} MiB)",
        agg.total_tps(),
        bytes_qlora as f64 / (1024.0 * 1024.0)
    );
    row(&mut t, "QLoRA one engine per tenant", n_tenants, bytes_qlora, &agg);

    t.print();
    lords::bench::baseline::write_tables(
        "table5_multitenant",
        "BENCH_table5_multitenant.json",
        full,
        &[t],
    );
    println!(
        "\n(shape check: LoRDS multi-tenant ≈ LoRDS single-tenant throughput, \
         ≈ 1/{n_tenants} the QLoRA deployment's weight bytes — base counted once)"
    );
}
