//! Continuous-batching + shared-prefix serving bench — the two ROADMAP
//! success criteria for the chunked scheduler:
//!
//! 1. **TTFT vs longest co-resident prompt.** A mixed long/short open-loop
//!    trace is replayed against the lockstep schedule
//!    (`prefill_chunk_tokens = 0`: a whole prompt per tick) and the
//!    chunked schedule (one KV block per tick). Short-request TTFT p99
//!    under lockstep scales with the longest prompt admitted beside it;
//!    under the chunked schedule it stays bounded by the chunk size.
//! 2. **KV blocks vs shared-prefix session count.** N concurrent sessions
//!    over one system prefix are served with prefix sharing on and off:
//!    shared, the prefix's blocks are stored (and prefilled) once and the
//!    per-session cost is the private tail — O(1) in the prefix; unshared,
//!    both grow O(N · prefix).
//!
//! Results are written to `BENCH_serve_prefix.json` (override with
//! `LORDS_BENCH_JSON=path`).

use lords::config::ServeCfg;
use lords::coordinator::{run_open_loop, Event, NativeEngine, Request, Server};
use lords::kvquant::{KvBits, KvQuantCfg};
use lords::quant::lords::RefineCfg;
use lords::quant::Codebook;
use lords::report::testbed::{full_mode, model_zoo, Testbed};
use lords::util::Rng;

struct TtftPoint {
    longest_prompt: usize,
    chunk_tokens: usize,
    short_ttft_p99_ms: f64,
    prefill_chunks: usize,
    completed: usize,
}

struct PrefixPoint {
    sessions: usize,
    sharing: bool,
    peak_kv_blocks: usize,
    prefill_tokens: usize,
    prefix_hit_tokens: usize,
}

fn p99(mut xs: Vec<f64>) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() as f64 * 0.99).ceil() as usize - 1).min(xs.len() - 1)]
}

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner(
        "Serve prefix",
        "chunked-prefill TTFT isolation + shared-prefix KV reuse (continuous batching)",
    );

    let full = full_mode();
    let (name, cfg) = model_zoo().remove(0);
    let tb = Testbed::build(name, &cfg, if full { 200 } else { 60 }, 0);
    let mut model = tb.model.clone();
    model.quantize_lords(
        cfg.block,
        &Codebook::normal_float(4),
        RefineCfg { steps: if full { 30 } else { 10 }, ..Default::default() },
        false,
    );
    let kv = KvQuantCfg::with_bits(KvBits::Int8);
    let bt = kv.block_tokens;
    let serve = |chunk: usize| ServeCfg {
        batch_window_us: 0,
        kv_bits: 8,
        prefill_chunk_tokens: chunk,
        ..Default::default()
    };

    // ---- 1: short-request TTFT p99 vs the longest co-resident prompt
    let n_short = if full { 24 } else { 12 };
    let n_long = if full { 6 } else { 3 };
    let short_len = bt;
    let max_new = 8;
    let mut t = lords::bench::TableBuilder::new(
        "Short-request TTFT p99 vs longest co-resident prompt (open loop, int8 KV)",
    )
    .headers(&["Longest prompt", "Schedule", "Short TTFT p99 ms", "Prefill chunks", "Done"]);
    let mut ttft_points: Vec<TtftPoint> = Vec::new();
    for frac in [4usize, 2] {
        let long_len = cfg.max_seq / frac;
        for chunk in [0usize, bt] {
            let mut server = Server::new(
                NativeEngine::with_kv(model.clone(), "ttft", kv),
                serve(chunk),
            ).unwrap();
            // every 5th request is a long prompt; ids < 1000 are short
            let mut rng = Rng::new(7);
            let reqs: Vec<Request> = (0..n_short + n_long)
                .map(|i| {
                    let (id, plen) = if i % 5 == 0 && i / 5 < n_long {
                        (1000 + i as u64, long_len)
                    } else {
                        (i as u64, short_len)
                    };
                    Request::new(id, (0..plen).map(|_| rng.below(cfg.vocab)).collect(), max_new)
                })
                .collect();
            let report = run_open_loop(&mut server, reqs, 200.0, 11).unwrap();
            let short_ttfts: Vec<f64> = report
                .responses
                .iter()
                .filter(|r| r.id < 1000)
                .map(|r| r.ttft_s * 1e3)
                .collect();
            let p = TtftPoint {
                longest_prompt: long_len,
                chunk_tokens: chunk,
                short_ttft_p99_ms: p99(short_ttfts),
                prefill_chunks: report.metrics.prefill_chunks,
                completed: report.metrics.completed,
            };
            t.row(vec![
                long_len.to_string(),
                if chunk == 0 { "lockstep".into() } else { format!("chunked({chunk})") },
                format!("{:.3}", p.short_ttft_p99_ms),
                p.prefill_chunks.to_string(),
                p.completed.to_string(),
            ]);
            ttft_points.push(p);
        }
    }
    t.print();
    println!(
        "\n(shape check: lockstep short-TTFT p99 grows with the longest prompt; \
         chunked stays near the one-chunk tick time)"
    );

    // ---- 2: KV blocks and prefill tokens vs shared-prefix session count
    let prefix_len = cfg.max_seq / 2; // block-aligned: max_seq is a block multiple
    let tail_len = 8;
    let mut t = lords::bench::TableBuilder::new(
        "KV footprint for N sessions over one shared prefix (int8 KV)",
    )
    .headers(&["Sessions", "Prefix sharing", "Peak KV blocks", "Prefill tokens", "Hit tokens"]);
    let mut prefix_points: Vec<PrefixPoint> = Vec::new();
    for n in [1usize, 2, 4, 8] {
        for sharing in [true, false] {
            let mut engine = NativeEngine::with_kv(model.clone(), "prefix", kv);
            engine.set_prefix_sharing(sharing);
            let mut server = Server::new(engine, serve(bt)).unwrap();
            let mut rng = Rng::new(13);
            let prefix: Vec<usize> = (0..prefix_len).map(|_| rng.below(cfg.vocab)).collect();
            let session = |id: u64, rng: &mut Rng| {
                let mut prompt = prefix.clone();
                prompt.extend((0..tail_len).map(|_| rng.below(cfg.vocab)));
                Request::new(id, prompt, max_new)
            };
            // warm the cache with one untracked session, then reset metrics
            server.submit(session(999, &mut rng)).unwrap();
            while !server.is_idle() {
                server.step().unwrap();
            }
            server.reset_metrics();
            let warm_blocks = server.engine.kv_pool().used_blocks();
            // N concurrent sessions over the same prefix
            for id in 0..n as u64 {
                server.submit(session(id, &mut rng)).unwrap();
            }
            let mut peak = warm_blocks;
            let mut done = 0;
            while !server.is_idle() {
                for ev in server.step().unwrap() {
                    if let Event::Done { .. } = ev {
                        done += 1;
                    }
                }
                peak = peak.max(server.engine.kv_pool().used_blocks());
            }
            assert_eq!(done, n, "all sessions complete");
            let p = PrefixPoint {
                sessions: n,
                sharing,
                peak_kv_blocks: peak,
                prefill_tokens: server.metrics.prefill_tokens,
                prefix_hit_tokens: server.metrics.prefix_hit_tokens,
            };
            t.row(vec![
                n.to_string(),
                if sharing { "on".into() } else { "off".to_string() },
                p.peak_kv_blocks.to_string(),
                p.prefill_tokens.to_string(),
                p.prefix_hit_tokens.to_string(),
            ]);
            prefix_points.push(p);
        }
    }
    t.print();
    println!(
        "\n(shape check: with sharing on, peak blocks ≈ prefix/block_tokens + N·tail and \
         prefill tokens grow by the tail only — O(1) in the prefix; off, both grow O(N·prefix))"
    );
    write_json(&ttft_points, &prefix_points, full);
}

fn write_json(ttft: &[TtftPoint], prefix: &[PrefixPoint], full: bool) {
    let path = std::env::var("LORDS_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve_prefix.json").to_string()
    });
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"serve_prefix\",\n");
    s.push_str("  \"unit\": \"milliseconds_blocks_and_tokens\",\n");
    s.push_str(&format!("  \"full_mode\": {full},\n"));
    s.push_str(&format!("  \"threads\": {},\n", lords::util::ThreadPool::global().size()));
    s.push_str("  \"measured\": true,\n");
    s.push_str("  \"ttft_vs_longest_prompt\": [\n");
    for (i, p) in ttft.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"longest_prompt\": {}, \"prefill_chunk_tokens\": {}, \
             \"short_ttft_p99_ms\": {:.3}, \"prefill_chunks\": {}, \"completed\": {}}}{}\n",
            p.longest_prompt,
            p.chunk_tokens,
            p.short_ttft_p99_ms,
            p.prefill_chunks,
            p.completed,
            if i + 1 == ttft.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"kv_blocks_vs_shared_sessions\": [\n");
    for (i, p) in prefix.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"sessions\": {}, \"prefix_sharing\": {}, \"peak_kv_blocks\": {}, \
             \"prefill_tokens\": {}, \"prefix_hit_tokens\": {}}}{}\n",
            p.sessions,
            p.sharing,
            p.peak_kv_blocks,
            p.prefill_tokens,
            p.prefix_hit_tokens,
            if i + 1 == prefix.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[serve_prefix] wrote baseline {path}"),
        Err(e) => eprintln!("[serve_prefix] could not write {path}: {e}"),
    }
}
