//! Table 2 — impact of iterative refinement (Algorithm 1): QuantError
//! (nuclear norm of the residual, averaged over modules), Wiki PPL, and
//! average accuracy, with refinement off (SVD init only) vs on.
//!
//! Expected shape: refinement strictly reduces QuantError and Wiki PPL and
//! lifts the average, at every block size.

use lords::bench::table::f2;
use lords::bench::TableBuilder;
use lords::model::LinearWeight;
use lords::quant::error::quant_error_nuclear;
use lords::quant::lords::RefineCfg;
use lords::quant::{Codebook, QuantizedLinear};
use lords::report::testbed::{eval_model, full_mode, model_zoo, Testbed};

fn main() {
    lords::util::logging::init();
    lords::bench::harness::banner("Table 2", "iterative refinement: QuantError / PPL / Avg");

    let full = full_mode();
    let zoo = model_zoo();
    let models: Vec<_> = if full { zoo } else { zoo.into_iter().take(1).collect() };
    let blocks: Vec<usize> = if full { vec![64, 128] } else { vec![64] };
    let pretrain = if full { 300 } else { 120 };
    let refine_steps = if full { 500 } else { 120 };

    let mut t = TableBuilder::new("Table 2 — refinement impact")
        .headers(&["Model", "BlockSize", "Iter.", "QuantError ↓", "Wiki ↓", "Avg ↑"]);

    for (name, cfg) in &models {
        let tb = Testbed::build(name, cfg, pretrain, 0);
        for &block in &blocks {
            for (iter_label, steps) in [("-", 0usize), ("yes", refine_steps)] {
                let cb = Codebook::normal_float(3); // nf3: the separation regime at testbed scale (see EXPERIMENTS.md §T1)
                let mut model = tb.model.clone();
                // snapshot the dense weights for the error metric
                let dense: Vec<_> = model
                    .layers
                    .iter()
                    .flat_map(|l| l.linears().into_iter().map(|(_, w)| w.effective()))
                    .collect();
                model.quantize_lords(block, &cb, RefineCfg { steps, lr: 0.05, requant_every: 5 }, false);
                let mut err = 0.0f32;
                let mut count = 0;
                for (lw, w0) in model
                    .layers
                    .iter()
                    .flat_map(|l| l.linears().into_iter().map(|(_, w)| w))
                    .zip(&dense)
                {
                    if let LinearWeight::Lords { q, .. } = lw {
                        err += quant_error_nuclear(w0, &q.dequantize());
                        count += 1;
                    }
                }
                err /= count as f32;
                let e = eval_model(&model, &tb, 8, 16);
                eprintln!(
                    "[table2] {name} b{block} iter={iter_label} err {err:.3} wiki {}",
                    e.wiki.display()
                );
                t.row(vec![
                    name.to_string(),
                    block.to_string(),
                    iter_label.into(),
                    f2(err),
                    e.wiki.display(),
                    f2(e.avg),
                ]);
            }
        }
    }
    t.print();
    lords::bench::baseline::write_tables("table2_refine", "BENCH_table2_refine.json", full, &[t]);
    println!("\n(shape check: 'yes' rows must beat '-' rows on all three metrics)");
}
