//! Figure 2 — operator latency vs token count M, and the systems claim
//! behind it: element-wise scaling served by the **fused bit-packed**
//! kernels costs the same as block-wise, and both beat the materializing
//! dequantize-then-GEMM path the fused kernels replace.
//!
//! Per (n, m) shape and bit width, each sweep point times:
//! * `dense`        — fp32 GEMM over the original weight (upper bound on
//!   memory traffic, no quantization);
//! * `dequant+GEMM` — `matmul_transb(x, lords.dequantize())`: materialize
//!   Ŵ then GEMM (the seed's serving path);
//! * `bnb NF4`      — fused packed block-wise kernel;
//! * `LoRDS`        — fused packed LoRDS kernel (rank-r scale
//!   reconstruction per row-tile);
//! * `QLoRA`        — fused packed base + the unmergeable adapter GEMMs.
//!
//! Expected shape: LoRDS tracks NF4 within a few %, both are no slower
//! than dequant+GEMM (strictly faster at m = k = 2048), and QLoRA sits
//! strictly above (Figure 2's latency gap).
//!
//! Results are also written as a machine-readable baseline to
//! `BENCH_fig2.json` (override with `LORDS_BENCH_JSON=path`) so later PRs
//! have a perf trajectory to compare against.

use lords::bench::harness::{banner, bench_fn};
use lords::bench::TableBuilder;
use lords::quant::baselines::QloraLinear;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{BlockwiseQuant, Codebook, QuantizedLinear};
use lords::report::testbed::{full_mode, llm_like_weight, ModuleShape};
use lords::runtime::executor::Executor;
use lords::runtime::HostTensor;
use lords::tensor::{matmul_transb, Matrix};
use lords::util::Rng;

struct Point {
    n: usize,
    m: usize,
    bits: u32,
    tokens: usize,
    dense_ms: f64,
    dequant_gemm_ms: f64,
    nf4_ms: f64,
    lords_ms: f64,
    qlora_ms: f64,
}

#[allow(clippy::too_many_arguments)] // bench crates don't see lib.rs's crate-level allow
fn sweep_shape(
    n: usize,
    m: usize,
    block: usize,
    bits: u32,
    m_sweep: &[usize],
    refine_steps: usize,
    full: bool,
    out: &mut Vec<Point>,
) {
    let cb = Codebook::normal_float(bits);
    let mut rng = Rng::new(n as u64 ^ (bits as u64) << 32);
    let w = llm_like_weight(ModuleShape { name: "Q", n, m }, &mut rng);

    let bw = BlockwiseQuant::quantize(&w, block, &cb);
    let (lords, _) =
        LordsQuant::quantize(&w, block, &cb, RefineCfg { steps: refine_steps, ..Default::default() });
    let mut qlora = QloraLinear::new(&w, block, 16, &cb, &mut rng);
    rng.fill_normal(&mut qlora.lora_b.data, 0.0, 0.01);

    let mut t = TableBuilder::new(&format!(
        "Figure 2 — native kernels, {n}x{m} nf{bits} block {block} (ms per call; packed {:.1} KiB vs dense {:.1} KiB)",
        lords.weight_bytes() as f64 / 1024.0,
        (4 * n * m) as f64 / 1024.0
    ))
    .headers(&[
        "M",
        "dense fp32",
        "dequant+GEMM",
        "bnb NF4",
        "LoRDS",
        "QLoRA",
        "LoRDS/NF4",
        "fused/dequant",
    ]);
    for &mm in m_sweep {
        let x = Matrix::randn(mm, m, 1.0, &mut rng);
        let (wu, me) = (0.1, if full { 1.0 } else { 0.3 });
        let r_dense = bench_fn("dense", wu, me, || {
            std::hint::black_box(matmul_transb(&x, &w));
        });
        let r_dequant = bench_fn("dequant+gemm", wu, me, || {
            // the seed's path: materialize Ŵ, then GEMM
            let w_hat = lords.dequantize();
            std::hint::black_box(matmul_transb(&x, &w_hat));
        });
        let r_nf4 = bench_fn("nf4", wu, me, || {
            std::hint::black_box(bw.matmul_transb(&x));
        });
        let r_lords = bench_fn("lords", wu, me, || {
            std::hint::black_box(lords.matmul_transb(&x));
        });
        let r_qlora = bench_fn("qlora", wu, me, || {
            std::hint::black_box(qlora.forward(&x));
        });
        eprintln!(
            "[fig2] {n}x{m} nf{bits} M={mm}: dense {:.2} dequant {:.2} nf4 {:.2} lords {:.2} qlora {:.2} (ms)",
            r_dense.mean_ms(),
            r_dequant.mean_ms(),
            r_nf4.mean_ms(),
            r_lords.mean_ms(),
            r_qlora.mean_ms()
        );
        t.row(vec![
            mm.to_string(),
            format!("{:.3}", r_dense.mean_ms()),
            format!("{:.3}", r_dequant.mean_ms()),
            format!("{:.3}", r_nf4.mean_ms()),
            format!("{:.3}", r_lords.mean_ms()),
            format!("{:.3}", r_qlora.mean_ms()),
            format!("{:.2}x", r_lords.mean_s / r_nf4.mean_s),
            format!("{:.2}x", r_lords.mean_s / r_dequant.mean_s),
        ]);
        out.push(Point {
            n,
            m,
            bits,
            tokens: mm,
            dense_ms: r_dense.mean_ms(),
            dequant_gemm_ms: r_dequant.mean_ms(),
            nf4_ms: r_nf4.mean_ms(),
            lords_ms: r_lords.mean_ms(),
            qlora_ms: r_qlora.mean_ms(),
        });
    }
    t.print();
}

fn write_json(points: &[Point], full: bool) {
    // default to the repo-root baseline file (cargo runs bench binaries
    // with cwd = the package dir, i.e. rust/)
    let path = std::env::var("LORDS_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fig2.json").to_string()
    });
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"fig2_kernel_latency\",\n");
    s.push_str("  \"unit\": \"ms_per_call_mean\",\n");
    s.push_str(&format!("  \"full_mode\": {full},\n"));
    s.push_str(&format!("  \"threads\": {},\n", lords::util::ThreadPool::global().size()));
    s.push_str("  \"results\": [\n");
    for (i, p) in points.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"n\": {}, \"m\": {}, \"bits\": {}, \"tokens\": {}, \
             \"dense_gemm_ms\": {:.4}, \"dequant_gemm_ms\": {:.4}, \"fused_nf4_ms\": {:.4}, \
             \"fused_lords_ms\": {:.4}, \"qlora_ms\": {:.4}}}{}\n",
            p.n,
            p.m,
            p.bits,
            p.tokens,
            p.dense_ms,
            p.dequant_gemm_ms,
            p.nf4_ms,
            p.lords_ms,
            p.qlora_ms,
            if i + 1 == points.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write(&path, &s) {
        Ok(()) => eprintln!("[fig2] wrote baseline {path}"),
        Err(e) => eprintln!("[fig2] could not write {path}: {e}"),
    }
}

fn main() {
    lords::util::logging::init();
    banner("Figure 2", "fused packed kernels vs dequant+GEMM vs dense (latency per call)");

    let full = full_mode();
    let block = 64usize;
    let mut points = Vec::new();

    // q_proj-like shape across bit widths (2/3-bit only in FULL mode)
    let m_sweep: Vec<usize> = if full { vec![16, 64, 256, 1024] } else { vec![16, 64, 256] };
    let bit_sweep: Vec<u32> = if full { vec![2, 3, 4] } else { vec![4] };
    for &bits in &bit_sweep {
        sweep_shape(512, 512, block, bits, &m_sweep, if full { 50 } else { 30 }, full, &mut points);
    }

    // the acceptance shape: m = k = 2048 at 4 bits — fused must strictly
    // beat dequant+GEMM here (Ŵ materialization is 16 MiB per call)
    let m_sweep_big: Vec<usize> = if full { vec![16, 64, 256] } else { vec![16, 64] };
    sweep_shape(2048, 2048, block, 4, &m_sweep_big, if full { 20 } else { 8 }, full, &mut points);

    write_json(&points, full);

    // PJRT path (Pallas kernels lowered to HLO), unchanged protocol
    match Executor::spawn("artifacts") {
        Ok(exec) => {
            let manifest = lords::runtime::Manifest::load("artifacts").unwrap();
            let h = exec.handle();
            let mut t2 = TableBuilder::new("Figure 2 — PJRT Pallas kernels (ms per call)")
                .headers(&["M", "fp GEMM", "bnb NF4", "QLoRA", "LoRDS", "LoRDS/NF4", "QLoRA/NF4"]);
            // kernel artifacts were lowered at n=m=512, block=64, r=parity
            let r = lords::quant::parity_rank(512, 512, 64);
            let mut rng2 = Rng::new(3);
            let codes: Vec<i32> = (0..512 * 512).map(|_| rng2.below(16) as i32).collect();
            let bmat: Vec<f32> = (0..512 * r).map(|_| rng2.normal() * 0.1 + 0.5).collect();
            let amat: Vec<f32> = (0..r * 512).map(|_| rng2.normal() * 0.1 + 0.5).collect();
            let scales: Vec<f32> = (0..512 * 8).map(|_| rng2.f32() + 0.1).collect();
            let la: Vec<f32> = (0..16 * 512).map(|_| rng2.normal() * 0.02).collect();
            let lb: Vec<f32> = (0..512 * 16).map(|_| rng2.normal() * 0.02).collect();
            let lut = manifest.lut.clone();
            for &mm in &m_sweep {
                if manifest.artifact(&format!("lords_mm_m{mm}")).is_err() {
                    continue;
                }
                let x: Vec<f32> = (0..mm * 512).map(|_| rng2.normal()).collect();
                let wfp: Vec<f32> = (0..512 * 512).map(|_| rng2.normal() * 0.02).collect();
                let run = |name: String, inputs: Vec<HostTensor>| {
                    let h = h.clone();
                    h.warm(&name).unwrap();
                    let label = name.clone();
                    bench_fn(&label, 0.2, if full { 1.5 } else { 0.6 }, move || {
                        h.execute(&name, inputs.clone()).unwrap();
                    })
                };
                let r_fp = run(
                    format!("fp_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::F32(wfp.clone(), vec![512, 512]),
                    ],
                );
                let r_lords = run(
                    format!("lords_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(bmat.clone(), vec![512, r]),
                        HostTensor::F32(amat.clone(), vec![r, 512]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                let r_nf4 = run(
                    format!("nf4_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(scales.clone(), vec![512, 8]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                let r_qlora = run(
                    format!("qlora_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(scales.clone(), vec![512, 8]),
                        HostTensor::F32(la.clone(), vec![16, 512]),
                        HostTensor::F32(lb.clone(), vec![512, 16]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                eprintln!(
                    "[fig2] pjrt M={mm}: fp {:.2} nf4 {:.2} qlora {:.2} lords {:.2} (ms)",
                    r_fp.mean_ms(),
                    r_nf4.mean_ms(),
                    r_qlora.mean_ms(),
                    r_lords.mean_ms()
                );
                t2.row(vec![
                    mm.to_string(),
                    format!("{:.3}", r_fp.mean_ms()),
                    format!("{:.3}", r_nf4.mean_ms()),
                    format!("{:.3}", r_qlora.mean_ms()),
                    format!("{:.3}", r_lords.mean_ms()),
                    format!("{:.2}x", r_lords.mean_s / r_nf4.mean_s),
                    format!("{:.2}x", r_qlora.mean_s / r_nf4.mean_s),
                ]);
            }
            t2.print();
        }
        Err(e) => eprintln!("[fig2] PJRT sweep skipped ({e}) — run `make artifacts`"),
    }
    println!("\n(shape check: LoRDS/NF4 ≈ 1.0x, fused/dequant ≤ 1.0x — strictly < at 2048 — QLoRA above both)");
}
