//! Figure 2 — operator latency vs token count M for the scaled q_proj
//! shape: bitsandbytes-NF4 (blockwise), QLoRA (blockwise + adapter), and
//! LoRDS fused dequant-matmul.
//!
//! Two backends per point:
//! * native — the fused Rust kernels (`BlockwiseQuant::matmul_transb`,
//!   `QloraLinear::forward`, `LordsQuant::matmul_transb`);
//! * pjrt   — the AOT-lowered Pallas kernels (`{kind}_mm_m{M}` artifacts).
//!
//! Expected shape: LoRDS tracks NF4 within a few % (rank-r scale product
//! only) while QLoRA sits strictly above both (extra adapter GEMMs).

use lords::bench::harness::{banner, bench_fn};
use lords::bench::TableBuilder;
use lords::quant::baselines::QloraLinear;
use lords::quant::lords::{LordsQuant, RefineCfg};
use lords::quant::{BlockwiseQuant, Codebook};
use lords::report::testbed::{full_mode, llm_like_weight, ModuleShape};
use lords::runtime::executor::Executor;
use lords::runtime::HostTensor;
use lords::tensor::Matrix;
use lords::util::Rng;

fn main() {
    lords::util::logging::init();
    banner("Figure 2", "kernel latency vs processed tokens M (q_proj shape)");

    let full = full_mode();
    let (n, m, block) = (512usize, 512usize, 64usize);
    let m_sweep: Vec<usize> = if full { vec![64, 256, 1024, 4096] } else { vec![64, 256, 1024] };
    let cb = Codebook::normal_float(4);
    let mut rng = Rng::new(0);
    let w = llm_like_weight(ModuleShape { name: "Q", n, m }, &mut rng);

    let bw = BlockwiseQuant::quantize(&w, block, &cb);
    let (lords, _) = LordsQuant::quantize(&w, block, &cb, RefineCfg { steps: 30, ..Default::default() });
    let mut qlora = QloraLinear::new(&w, block, 16, &cb, &mut rng);
    rng.fill_normal(&mut qlora.lora_b.data, 0.0, 0.01);

    let mut t = TableBuilder::new("Figure 2 — native fused kernels (ms per call)")
        .headers(&["M", "bnb NF4", "QLoRA", "LoRDS", "LoRDS/NF4", "QLoRA/NF4"]);
    for &mm in &m_sweep {
        let x = Matrix::randn(mm, m, 1.0, &mut rng);
        let (wu, me) = (0.1, if full { 1.0 } else { 0.4 });
        let r_nf4 = bench_fn("nf4", wu, me, || {
            std::hint::black_box(bw.matmul_transb(&x));
        });
        let r_qlora = bench_fn("qlora", wu, me, || {
            std::hint::black_box(qlora.forward(&x));
        });
        let r_lords = bench_fn("lords", wu, me, || {
            std::hint::black_box(lords.matmul_transb(&x));
        });
        eprintln!(
            "[fig2] native M={mm}: nf4 {:.2}ms qlora {:.2}ms lords {:.2}ms",
            r_nf4.mean_ms(),
            r_qlora.mean_ms(),
            r_lords.mean_ms()
        );
        t.row(vec![
            mm.to_string(),
            format!("{:.3}", r_nf4.mean_ms()),
            format!("{:.3}", r_qlora.mean_ms()),
            format!("{:.3}", r_lords.mean_ms()),
            format!("{:.2}x", r_lords.mean_s / r_nf4.mean_s),
            format!("{:.2}x", r_qlora.mean_s / r_nf4.mean_s),
        ]);
    }
    t.print();

    // PJRT path (Pallas kernels lowered to HLO)
    match Executor::spawn("artifacts") {
        Ok(exec) => {
            let manifest = lords::runtime::Manifest::load("artifacts").unwrap();
            let h = exec.handle();
            let mut t2 = TableBuilder::new("Figure 2 — PJRT Pallas kernels (ms per call)")
                .headers(&["M", "fp GEMM", "bnb NF4", "QLoRA", "LoRDS", "LoRDS/NF4", "QLoRA/NF4"]);
            // kernel artifacts were lowered at n=m=512, block=64, r=parity
            let r = lords::quant::parity_rank(512, 512, 64);
            let mut rng2 = Rng::new(3);
            let codes: Vec<i32> = (0..512 * 512).map(|_| rng2.below(16) as i32).collect();
            let bmat: Vec<f32> = (0..512 * r).map(|_| rng2.normal() * 0.1 + 0.5).collect();
            let amat: Vec<f32> = (0..r * 512).map(|_| rng2.normal() * 0.1 + 0.5).collect();
            let scales: Vec<f32> = (0..512 * 8).map(|_| rng2.f32() + 0.1).collect();
            let la: Vec<f32> = (0..16 * 512).map(|_| rng2.normal() * 0.02).collect();
            let lb: Vec<f32> = (0..512 * 16).map(|_| rng2.normal() * 0.02).collect();
            let lut = manifest.lut.clone();
            for &mm in &m_sweep {
                if manifest.artifact(&format!("lords_mm_m{mm}")).is_err() {
                    continue;
                }
                let x: Vec<f32> = (0..mm * 512).map(|_| rng2.normal()).collect();
                let wfp: Vec<f32> = (0..512 * 512).map(|_| rng2.normal() * 0.02).collect();
                let run = |name: String, inputs: Vec<HostTensor>| {
                    let h = h.clone();
                    h.warm(&name).unwrap();
                    let label = name.clone();
                    bench_fn(&label, 0.2, if full { 1.5 } else { 0.6 }, move || {
                        h.execute(&name, inputs.clone()).unwrap();
                    })
                };
                let r_fp = run(
                    format!("fp_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::F32(wfp.clone(), vec![512, 512]),
                    ],
                );
                let r_lords = run(
                    format!("lords_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(bmat.clone(), vec![512, r]),
                        HostTensor::F32(amat.clone(), vec![r, 512]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                let r_nf4 = run(
                    format!("nf4_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(scales.clone(), vec![512, 8]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                let r_qlora = run(
                    format!("qlora_mm_m{mm}"),
                    vec![
                        HostTensor::F32(x.clone(), vec![mm, 512]),
                        HostTensor::I32(codes.clone(), vec![512, 512]),
                        HostTensor::F32(scales.clone(), vec![512, 8]),
                        HostTensor::F32(la.clone(), vec![16, 512]),
                        HostTensor::F32(lb.clone(), vec![512, 16]),
                        HostTensor::F32(lut.clone(), vec![lut.len()]),
                    ],
                );
                eprintln!(
                    "[fig2] pjrt M={mm}: fp {:.2} nf4 {:.2} qlora {:.2} lords {:.2} (ms)",
                    r_fp.mean_ms(),
                    r_nf4.mean_ms(),
                    r_qlora.mean_ms(),
                    r_lords.mean_ms()
                );
                t2.row(vec![
                    mm.to_string(),
                    format!("{:.3}", r_fp.mean_ms()),
                    format!("{:.3}", r_nf4.mean_ms()),
                    format!("{:.3}", r_qlora.mean_ms()),
                    format!("{:.3}", r_lords.mean_ms()),
                    format!("{:.2}x", r_lords.mean_s / r_nf4.mean_s),
                    format!("{:.2}x", r_qlora.mean_s / r_nf4.mean_s),
                ]);
            }
            t2.print();
        }
        Err(e) => eprintln!("[fig2] PJRT sweep skipped ({e}) — run `make artifacts`"),
    }
    println!("\n(shape check: LoRDS/NF4 ≈ 1.0x, QLoRA/NF4 > 1.0x across the sweep)");
}
