//! `repolint` — source-level invariant checker for this repository.
//!
//! The serving core leans on hand-rolled `unsafe` (the raw-pointer
//! `parallel_for` fan-out in `util::pool`, lock-free trace segments in
//! `obs::trace`, packed-code kernels) and on contracts rustc cannot see:
//! the serving path must not panic, the per-token hot path must not
//! allocate, every exported metric must be documented. This tool turns
//! those reviewer-enforced contracts into a hard CI gate
//! (`cargo run -p repolint`, exit 0 means clean).
//!
//! Diagnostics name the rule ID, its slug, and the site:
//!
//! ```text
//! repolint: E0003 [panic-free-serving] rust/src/coordinator/server.rs:412 — `.unwrap()` ...
//! ```
//!
//! | rule  | slug               | invariant                                              | escape hatch            |
//! |-------|--------------------|--------------------------------------------------------|-------------------------|
//! | E0001 | safety-comment     | every `unsafe` is immediately preceded by `// SAFETY:` | `// SAFETY: <why>`      |
//! | E0002 | unsafe-allowlist   | `unsafe` only in the audited module allow-list         | `// UNSAFE-OK: <why>`   |
//! | E0003 | panic-free-serving | no unwrap/expect/panic!/unreachable! on serving paths  | `// PANIC-OK: <why>`    |
//! | E0004 | hot-path-alloc     | no `Vec::new`/`vec![`/`.to_vec()`/`.clone()` in the    | `// ALLOC-OK: <why>`    |
//! |       |                    | `_into` forwards and per-token decode functions        |                         |
//! | E0005 | metrics-discipline | every registered metric has help text + a README row   | `// METRIC-OK: <why>`   |
//! | E0006 | module-map         | every top-level `pub mod` has a `lib.rs` map row       | `// MODMAP-OK: <why>`   |
//! | E0007 | bench-discipline   | every `[[bench]]` is smoke-aware and writes a          | `// BENCH-OK: <why>`    |
//! |       |                    | `BENCH_*.json` baseline                                |                         |
//! | E0008 | fault-site-table   | every `fault::point!` site name is a string literal    | `// FAULT-OK: <why>`    |
//! |       |                    | with a row in the README fault-site table              |                         |
//!
//! `// REPOLINT-OK: <why>` suppresses any rule at a site. Annotations
//! count when they sit on the flagged line, or in the comment block (and
//! attribute lines) immediately above it — a blank line breaks the block.
//!
//! The scanner is a hand-rolled line/token pass in the house style of
//! `obs::json`: comments and string contents are blanked (preserving
//! column alignment) before token searches, `#[cfg(test)]` regions are
//! tracked by brace depth and exempted from E0003/E0005, and E0004
//! extracts the configured hot-function bodies by brace matching.
//! Deliberately NOT covered: `assert!`/`debug_assert!` (invariant checks
//! are encouraged), and allocation in cold setup paths.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Modules where `unsafe` is permitted (E0002). Everything here was
/// audited for this list; new entries need the same audit.
const UNSAFE_ALLOWED: &[&str] = &[
    "rust/src/kernels/fused.rs",
    "rust/src/tensor/gemm.rs",
    "rust/src/util/pool.rs",
    "rust/src/obs/trace.rs",
    "rust/src/kvquant/attention.rs",
    "rust/src/quant/lords.rs",
    "rust/src/quant/blockwise.rs",
];

/// Serving-path scope for E0003 (panic-free-serving).
const SERVING_PREFIXES: &[&str] = &["rust/src/coordinator/", "rust/src/kvquant/"];
const SERVING_FILES: &[&str] = &["rust/src/obs/http.rs"];

/// Hot functions for E0004: the `_into` forwards and per-token decode
/// functions the decode path runs per tick, de-allocated in the batching
/// PR. A configured name that no longer resolves is itself a violation,
/// so renames keep this list honest.
const HOT_FUNCTIONS: &[(&str, &[&str])] = &[
    ("rust/src/tensor/gemm.rs", &["matmul_transb_into"]),
    (
        "rust/src/kernels/fused.rs",
        &["lords_matmul_transb_into", "lords_matmul_transb_adapter_into", "blockwise_matmul_transb_into"],
    ),
    ("rust/src/quant/lords.rs", &["matmul_transb_opt_into"]),
    ("rust/src/quant/blockwise.rs", &["matmul_transb_into"]),
    ("rust/src/model/linear.rs", &["forward_into", "forward_adapted_into"]),
    ("rust/src/model/norm.rs", &["rmsnorm_fwd_into"]),
    ("rust/src/model/transformer.rs", &["decode_batch_pooled"]),
    ("rust/src/kvquant/attention.rs", &["decode_packed_into", "decode_packed_batch"]),
    ("rust/src/kvquant/pool.rs", &["append_row", "k_row_into", "v_row_into"]),
];

const ALLOC_TOKENS: &[&str] = &["Vec::new", "vec![", ".to_vec()", ".clone()"];

const PANIC_TOKENS: &[(&str, &str)] = &[
    (".unwrap()", "`.unwrap()`"),
    (".expect(", "`.expect(..)`"),
    ("panic!", "`panic!`"),
    ("unreachable!", "`unreachable!`"),
    ("todo!", "`todo!`"),
    ("unimplemented!", "`unimplemented!`"),
];

struct Violation {
    rule: &'static str,
    slug: &'static str,
    file: String,
    line: usize,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "repolint: {} [{}] {}:{} — {}", self.rule, self.slug, self.file, self.line, self.msg)
    }
}

/// A scanned source file: original lines, code with comments and string
/// contents blanked (1:1 by char index — quotes kept), the comment text
/// per line, and the `#[cfg(test)]`-region mask.
struct Scan {
    raw: Vec<String>,
    code: Vec<String>,
    comments: Vec<String>,
    in_test: Vec<bool>,
}

fn scan_source(text: &str) -> Scan {
    #[derive(PartialEq, Clone, Copy)]
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u8),
    }
    let chars: Vec<char> = text.chars().collect();
    let mut raw_lines = Vec::new();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let (mut raw, mut code, mut comment) = (String::new(), String::new(), String::new());
    let mut st = St::Code;
    let mut last_code: Option<char> = None;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::Line {
                st = St::Code;
            }
            raw_lines.push(std::mem::take(&mut raw));
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        raw.push(c);
        match st {
            St::Line => {
                comment.push(c);
                code.push(' ');
                i += 1;
            }
            St::Block(depth) => {
                comment.push(c);
                code.push(' ');
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    raw.push('*');
                    comment.push('*');
                    code.push(' ');
                    st = St::Block(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    raw.push('/');
                    comment.push('/');
                    code.push(' ');
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && chars.get(i + 1).is_some_and(|&n| n != '\n') {
                    raw.push(chars[i + 1]);
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                let closes = c == '"'
                    && (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'));
                if closes {
                    code.push('"');
                    for _ in 0..hashes {
                        raw.push('#');
                        code.push('#');
                    }
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::Code => {
                let ident_prev = last_code.is_some_and(|p| p.is_alphanumeric() || p == '_');
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    raw.push('/');
                    comment.push_str("//");
                    code.push_str("  ");
                    st = St::Line;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    raw.push('*');
                    comment.push_str("/*");
                    code.push_str("  ");
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ident_prev && raw_string_at(&chars, i) {
                    // consume the full r#..." / br#..." prefix as code
                    let mut j = i;
                    if c == 'b' {
                        code.push('b');
                        j += 1;
                        if chars[j] == 'r' {
                            raw.push('r');
                            code.push('r');
                            j += 1;
                        }
                    } else {
                        code.push('r');
                        j += 1;
                    }
                    let mut hashes = 0u8;
                    while chars.get(j) == Some(&'#') {
                        raw.push('#');
                        code.push('#');
                        hashes += 1;
                        j += 1;
                    }
                    raw.push('"');
                    code.push('"');
                    st = if hashes == 0 && c == 'b' && chars[i + 1] == '"' {
                        St::Str // b"..." has escapes like a normal string
                    } else {
                        St::RawStr(hashes)
                    };
                    i = j + 1;
                } else if c == '\'' {
                    if chars.get(i + 1) == Some(&'\\') {
                        // escaped char literal: blank to the closing quote
                        code.push('\'');
                        i += 1;
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            raw.push(chars[i]);
                            code.push(' ');
                            i += 1;
                        }
                        if chars.get(i) == Some(&'\'') {
                            raw.push('\'');
                            code.push('\'');
                            i += 1;
                        }
                    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                        raw.push(chars[i + 1]);
                        raw.push('\'');
                        code.push('\'');
                        code.push(' ');
                        code.push('\'');
                        i += 3;
                    } else {
                        // lifetime — plain code
                        code.push('\'');
                        last_code = Some('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    last_code = Some(c);
                    i += 1;
                }
            }
        }
    }
    raw_lines.push(raw);
    code_lines.push(code);
    comment_lines.push(comment);
    let in_test = mark_tests(&code_lines);
    Scan { raw: raw_lines, code: code_lines, comments: comment_lines, in_test }
}

/// True when `chars[i]` starts a raw/byte string literal (`r"`, `r#"`,
/// `br"`, `b"`, ...). The caller already ruled out an identifier prefix.
fn raw_string_at(chars: &[char], i: usize) -> bool {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if chars.get(j) == Some(&'"') {
            return true;
        }
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// Mark lines inside `#[cfg(test)]` / `#[test]` items by brace depth.
fn mark_tests(code: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut regions: Vec<i64> = Vec::new();
    for (ln, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]")
            || line.contains("#[cfg(all(test")
            || line.trim() == "#[test]"
        {
            pending = true;
        }
        let mut test_here = pending || !regions.is_empty();
        for ch in line.chars() {
            match ch {
                '{' => {
                    if pending {
                        regions.push(depth);
                        pending = false;
                        test_here = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if regions.last() == Some(&depth) {
                        regions.pop();
                        test_here = true;
                    }
                }
                ';' => {
                    if pending && regions.is_empty() {
                        pending = false; // attribute on a declaration line
                    }
                }
                _ => {}
            }
        }
        in_test[ln] = test_here || !regions.is_empty();
    }
    in_test
}

/// True when line `ln` carries `tag` (or the blanket `REPOLINT-OK`) in its
/// own comment, or in the comment block (skipping attribute lines)
/// immediately above. A blank line terminates the block.
fn annotated(scan: &Scan, ln: usize, tag: &str) -> bool {
    let hit = |s: &str| s.contains(tag) || s.contains("REPOLINT-OK");
    if hit(&scan.comments[ln]) {
        return true;
    }
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let code = scan.code[i].trim();
        let com = scan.comments[i].trim();
        if !code.is_empty() {
            if code.starts_with("#[") || code.starts_with("#!") {
                if hit(com) {
                    return true;
                }
                continue;
            }
            return false;
        }
        if com.is_empty() {
            return false;
        }
        if hit(com) {
            return true;
        }
    }
    false
}

/// Word-bounded token search over blanked code.
fn find_word(code: &str, word: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let p = from + p;
        let e = p + word.len();
        let before = p == 0 || {
            let c = bytes[p - 1] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        let after = e >= code.len() || {
            let c = bytes[e] as char;
            !(c.is_ascii_alphanumeric() || c == '_')
        };
        if before && after {
            return Some(p);
        }
        from = e;
    }
    None
}

// ---------------------------------------------------------------------------
// E0001 / E0002 — unsafe discipline
// ---------------------------------------------------------------------------

fn check_unsafe(scan: &Scan, rel: &str, out: &mut Vec<Violation>) {
    let allowed = UNSAFE_ALLOWED.contains(&rel);
    let mut passed = vec![false; scan.code.len()];
    for ln in 0..scan.code.len() {
        if find_word(&scan.code[ln], "unsafe").is_none() {
            continue;
        }
        let mut ok = annotated(scan, ln, "SAFETY:");
        if !ok {
            // A run of consecutive unsafe lines (e.g. the paired
            // `unsafe impl Send`/`Sync`) shares one SAFETY block.
            let mut i = ln;
            while i > 0 {
                i -= 1;
                let code = scan.code[i].trim();
                if code.is_empty() {
                    break;
                }
                if code.starts_with("#[") {
                    continue;
                }
                if find_word(&scan.code[i], "unsafe").is_some() && passed[i] {
                    ok = true;
                }
                break;
            }
        }
        passed[ln] = ok;
        if !ok {
            out.push(Violation {
                rule: "E0001",
                slug: "safety-comment",
                file: rel.to_string(),
                line: ln + 1,
                msg: "`unsafe` without an immediately preceding `// SAFETY:` comment \
                      stating the invariant that makes it sound"
                    .to_string(),
            });
        }
        if !allowed && !annotated(scan, ln, "UNSAFE-OK:") {
            out.push(Violation {
                rule: "E0002",
                slug: "unsafe-allowlist",
                file: rel.to_string(),
                line: ln + 1,
                msg: "`unsafe` outside the audited module allow-list — move the code into \
                      an audited module or annotate `// UNSAFE-OK: <reason>`"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// E0003 — panic-free serving path
// ---------------------------------------------------------------------------

fn serving_path(rel: &str) -> bool {
    SERVING_PREFIXES.iter().any(|p| rel.starts_with(p)) || SERVING_FILES.contains(&rel)
}

fn check_panics(scan: &Scan, rel: &str, out: &mut Vec<Violation>) {
    if !serving_path(rel) {
        return;
    }
    for ln in 0..scan.code.len() {
        if scan.in_test[ln] {
            continue;
        }
        for (tok, label) in PANIC_TOKENS {
            if scan.code[ln].contains(tok) && !annotated(scan, ln, "PANIC-OK:") {
                out.push(Violation {
                    rule: "E0003",
                    slug: "panic-free-serving",
                    file: rel.to_string(),
                    line: ln + 1,
                    msg: format!(
                        "{label} on the serving path — return an error / RejectReason, \
                         or annotate `// PANIC-OK: <reason>` if it provably cannot fire"
                    ),
                });
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// E0004 — hot-path allocation freedom
// ---------------------------------------------------------------------------

/// Body spans `(open_line, close_line)` of every `fn name` in the file.
fn fn_bodies(scan: &Scan, name: &str) -> Vec<(usize, usize)> {
    let pat = format!("fn {name}");
    let mut out = Vec::new();
    let mut ln = 0;
    while ln < scan.code.len() {
        let code = &scan.code[ln];
        let pos = match code.find(&pat) {
            Some(p) => p,
            None => {
                ln += 1;
                continue;
            }
        };
        let after = pos + pat.len();
        let bounded = code[after..]
            .chars()
            .next()
            .is_none_or(|c| !(c.is_ascii_alphanumeric() || c == '_'));
        let led = pos == 0 || {
            let c = code.as_bytes()[pos - 1] as char;
            c == ' ' || c == '(' || c == '\t'
        };
        if !(bounded && led) {
            ln += 1;
            continue;
        }
        // find the body's opening '{'; a ';' first means a declaration
        let mut open = None;
        let (mut l, mut c) = (ln, after);
        'search: while l < scan.code.len() && l <= ln + 12 {
            let bytes = scan.code[l].as_bytes();
            while c < bytes.len() {
                match bytes[c] as char {
                    '{' => {
                        open = Some((l, c));
                        break 'search;
                    }
                    ';' => break 'search,
                    _ => {}
                }
                c += 1;
            }
            l += 1;
            c = 0;
        }
        let Some((bl, bc)) = open else {
            ln += 1;
            continue;
        };
        // brace-match to the end of the body
        let mut depth: i64 = 0;
        let (mut l2, mut c2) = (bl, bc);
        let mut end = None;
        'outer: while l2 < scan.code.len() {
            let bytes = scan.code[l2].as_bytes();
            while c2 < bytes.len() {
                match bytes[c2] as char {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            end = Some(l2);
                            break 'outer;
                        }
                    }
                    _ => {}
                }
                c2 += 1;
            }
            l2 += 1;
            c2 = 0;
        }
        match end {
            Some(e) => {
                out.push((bl, e));
                ln = e + 1;
            }
            None => ln += 1,
        }
    }
    out
}

fn check_hot_allocs(scan: &Scan, rel: &str, out: &mut Vec<Violation>) {
    let Some((_, fns)) = HOT_FUNCTIONS.iter().find(|(f, _)| *f == rel) else {
        return;
    };
    for name in *fns {
        let bodies = fn_bodies(scan, name);
        if bodies.is_empty() {
            out.push(Violation {
                rule: "E0004",
                slug: "hot-path-alloc",
                file: rel.to_string(),
                line: 1,
                msg: format!(
                    "configured hot function `{name}` not found — update the repolint \
                     HOT_FUNCTIONS list to match the rename"
                ),
            });
            continue;
        }
        for (lo, hi) in bodies {
            for ln in lo..=hi {
                for tok in ALLOC_TOKENS {
                    if scan.code[ln].contains(tok) && !annotated(scan, ln, "ALLOC-OK:") {
                        out.push(Violation {
                            rule: "E0004",
                            slug: "hot-path-alloc",
                            file: rel.to_string(),
                            line: ln + 1,
                            msg: format!(
                                "`{tok}` inside hot function `{name}` — reuse caller \
                                 scratch, or annotate `// ALLOC-OK: <reason>`"
                            ),
                        });
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// E0005 — metrics discipline
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum RegKind {
    Bare,
    WithHelp,
    SetHelp,
}

enum Arg {
    Lit(String),
    Ident(String),
    Opaque,
}

struct MetricCall {
    file: String,
    line: usize,
    arg: Arg,
    kind: RegKind,
    escaped: bool,
}

const METRIC_TOKENS: &[(&str, RegKind)] = &[
    (".counter_with_help(", RegKind::WithHelp),
    (".gauge_with_help(", RegKind::WithHelp),
    (".histogram_with_help(", RegKind::WithHelp),
    (".set_help(", RegKind::SetHelp),
    (".counter(", RegKind::Bare),
    (".gauge(", RegKind::Bare),
    (".histogram(", RegKind::Bare),
];

/// First argument of a call whose `(` sits at `(ln, col)` in blanked code:
/// a string literal (content recovered from the raw line), an identifier
/// (resolved against const strings later), or something opaque.
fn first_arg(scan: &Scan, ln: usize, col: usize) -> Arg {
    let (mut l, mut c) = (ln, col);
    while l < scan.code.len() && l <= ln + 8 {
        let code = &scan.code[l];
        let bytes = code.as_bytes();
        while c < bytes.len() && (bytes[c] as char).is_whitespace() {
            c += 1;
        }
        if c >= bytes.len() {
            l += 1;
            c = 0;
            continue;
        }
        let ch = bytes[c] as char;
        if ch == '"' {
            if let Some(off) = code[c + 1..].find('"') {
                let raw: Vec<char> = scan.raw[l].chars().collect();
                return Arg::Lit(raw[c + 1..c + 1 + off].iter().collect());
            }
            return Arg::Opaque;
        }
        if ch.is_ascii_alphabetic() || ch == '_' {
            let mut e = c;
            while e < bytes.len() {
                let k = bytes[e] as char;
                if k.is_ascii_alphanumeric() || k == '_' || k == ':' {
                    e += 1;
                } else {
                    break;
                }
            }
            let ident = code[c..e].trim_end_matches(':');
            let seg = ident.rsplit("::").next().unwrap_or(ident);
            return Arg::Ident(seg.to_string());
        }
        return Arg::Opaque;
    }
    Arg::Opaque
}

/// `const NAME: &str = "value";` definitions (metric-family constants).
fn collect_consts(scan: &Scan, consts: &mut HashMap<String, String>) {
    for (i, code) in scan.code.iter().enumerate() {
        let Some(p) = code.find("const ") else { continue };
        if !code.contains("str") {
            continue;
        }
        let rest = &code[p + 6..];
        let Some(colon) = rest.find(':') else { continue };
        let name = rest[..colon].trim();
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            continue;
        }
        let Some(q0) = code.find('"') else { continue };
        let Some(off) = code[q0 + 1..].find('"') else { continue };
        let raw: Vec<char> = scan.raw[i].chars().collect();
        consts.insert(name.to_string(), raw[q0 + 1..q0 + 1 + off].iter().collect());
    }
}

fn collect_metric_calls(scan: &Scan, rel: &str, regs: &mut Vec<MetricCall>) {
    for ln in 0..scan.code.len() {
        if scan.in_test[ln] {
            continue;
        }
        for (tok, kind) in METRIC_TOKENS {
            let mut from = 0;
            while let Some(p) = scan.code[ln][from..].find(tok) {
                let p = from + p;
                regs.push(MetricCall {
                    file: rel.to_string(),
                    line: ln + 1,
                    arg: first_arg(scan, ln, p + tok.len()),
                    kind: *kind,
                    escaped: annotated(scan, ln, "METRIC-OK:"),
                });
                from = p + tok.len();
            }
        }
    }
}

fn check_metrics(
    regs: &[MetricCall],
    consts: &HashMap<String, String>,
    readme: &str,
    out: &mut Vec<Violation>,
) {
    let resolve = |arg: &Arg| -> Option<String> {
        match arg {
            Arg::Lit(s) => Some(s.clone()),
            Arg::Ident(id) => consts.get(id).cloned(),
            Arg::Opaque => None,
        }
    };
    let helped: HashSet<String> = regs
        .iter()
        .filter(|r| r.kind != RegKind::Bare)
        .filter_map(|r| resolve(&r.arg))
        .collect();
    for r in regs {
        if r.escaped {
            continue;
        }
        let Some(name) = resolve(&r.arg) else {
            out.push(Violation {
                rule: "E0005",
                slug: "metrics-discipline",
                file: r.file.clone(),
                line: r.line,
                msg: "metric name is not a string literal or a known `const ...: &str` — \
                      use one, or annotate `// METRIC-OK: <reason>`"
                    .to_string(),
            });
            continue;
        };
        if r.kind == RegKind::SetHelp {
            continue;
        }
        if r.kind == RegKind::Bare && !helped.contains(&name) {
            out.push(Violation {
                rule: "E0005",
                slug: "metrics-discipline",
                file: r.file.clone(),
                line: r.line,
                msg: format!(
                    "metric `{name}` registered without help text — use the `_with_help` \
                     variant or a `set_help` call"
                ),
            });
        }
        if !readme.contains(&format!("`{name}`")) {
            out.push(Violation {
                rule: "E0005",
                slug: "metrics-discipline",
                file: r.file.clone(),
                line: r.line,
                msg: format!("metric `{name}` has no row in the README metrics table"),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// E0008 — fault-site table
// ---------------------------------------------------------------------------

/// A `fault::point!(..)` injection site found in production source.
struct FaultSite {
    file: String,
    line: usize,
    arg: Arg,
    escaped: bool,
}

/// Both spellings of the site macro. `fault::point!(` also covers the
/// `crate::fault::point!(` form used inside the crate.
const FAULT_TOKENS: &[&str] = &["fault::point!(", "fault_point!("];

fn collect_fault_sites(scan: &Scan, rel: &str, sites: &mut Vec<FaultSite>) {
    for ln in 0..scan.code.len() {
        if scan.in_test[ln] {
            continue;
        }
        for tok in FAULT_TOKENS {
            let mut from = 0;
            while let Some(p) = scan.code[ln][from..].find(tok) {
                let p = from + p;
                sites.push(FaultSite {
                    file: rel.to_string(),
                    line: ln + 1,
                    arg: first_arg(scan, ln, p + tok.len()),
                    escaped: annotated(scan, ln, "FAULT-OK:"),
                });
                from = p + tok.len();
            }
        }
    }
}

/// Every injection site must be a grep-able string literal with a row in
/// the README fault-site table — operators configure `--fault` specs by
/// these names, so an undocumented site is unusable and an interpolated
/// one is unfindable.
fn check_fault_sites(sites: &[FaultSite], readme: &str, out: &mut Vec<Violation>) {
    for s in sites {
        if s.escaped {
            continue;
        }
        let Arg::Lit(name) = &s.arg else {
            out.push(Violation {
                rule: "E0008",
                slug: "fault-site-table",
                file: s.file.clone(),
                line: s.line,
                msg: "fault site name is not a string literal — sites must be grep-able \
                      constants; use a literal, or annotate `// FAULT-OK: <reason>`"
                    .to_string(),
            });
            continue;
        };
        if !readme.contains(&format!("`{name}`")) {
            out.push(Violation {
                rule: "E0008",
                slug: "fault-site-table",
                file: s.file.clone(),
                line: s.line,
                msg: format!(
                    "fault site `{name}` has no row in the README fault-site table — \
                     document what the site guards and which kinds apply"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// E0006 — lib.rs module map
// ---------------------------------------------------------------------------

fn check_module_map(scan: &Scan, rel: &str, out: &mut Vec<Violation>) {
    let doc = scan.comments.join("\n");
    for (i, code) in scan.code.iter().enumerate() {
        let t = code.trim();
        let Some(rest) = t.strip_prefix("pub mod ") else { continue };
        let Some(name) = rest.strip_suffix(';') else { continue };
        let name = name.trim();
        if annotated(scan, i, "MODMAP-OK:") {
            continue;
        }
        if !doc.contains(&format!("[`{name}`]")) {
            out.push(Violation {
                rule: "E0006",
                slug: "module-map",
                file: rel.to_string(),
                line: i + 1,
                msg: format!(
                    "top-level module `{name}` has no row in the lib.rs module map — \
                     add `| [`{name}`] | ... |`, or annotate `// MODMAP-OK: <reason>`"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// E0007 — bench discipline
// ---------------------------------------------------------------------------

/// `(name, line-of-[[bench]])` entries from a Cargo.toml text.
fn bench_entries(manifest: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut bench_line = None;
    for (i, line) in manifest.lines().enumerate() {
        let t = line.trim();
        if t == "[[bench]]" {
            bench_line = Some(i + 1);
            continue;
        }
        if t.starts_with('[') {
            bench_line = None;
            continue;
        }
        if let Some(bl) = bench_line {
            if let Some(rest) = t.strip_prefix("name") {
                let rest = rest.trim_start();
                if let Some(v) = rest.strip_prefix('=') {
                    out.push((v.trim().trim_matches('"').to_string(), bl));
                    bench_line = None;
                }
            }
        }
    }
    out
}

/// Smoke-aware symbols: `smoke_mode()` and the harness/zoo entry points
/// that consult it internally (`full_mode` is false under smoke,
/// `model_zoo` shrinks, `bench_fn` caps its windows).
const SMOKE_TOKENS: &[&str] =
    &["smoke_mode", "LORDS_BENCH_SMOKE", "full_mode", "model_zoo", "bench_fn"];

fn check_bench_source(name: &str, line: usize, src: &str, out: &mut Vec<Violation>) {
    if src.contains("BENCH-OK") || src.contains("REPOLINT-OK") {
        return;
    }
    if !SMOKE_TOKENS.iter().any(|t| src.contains(t)) {
        out.push(Violation {
            rule: "E0007",
            slug: "bench-discipline",
            file: "rust/Cargo.toml".to_string(),
            line,
            msg: format!(
                "bench `{name}` never consults the smoke switch (`smoke_mode` / \
                 `LORDS_BENCH_SMOKE` / smoke-aware harness entry points) — CI runs every \
                 bench and needs it to shrink"
            ),
        });
    }
    if !src.contains("BENCH_") {
        out.push(Violation {
            rule: "E0007",
            slug: "bench-discipline",
            file: "rust/Cargo.toml".to_string(),
            line,
            msg: format!(
                "bench `{name}` writes no `BENCH_*.json` baseline — emit one (see \
                 `bench::baseline`), or annotate the bench source `// BENCH-OK: <reason>`"
            ),
        });
    }
}

fn check_benches(root: &Path, out: &mut Vec<Violation>) {
    let manifest = match fs::read_to_string(root.join("rust/Cargo.toml")) {
        Ok(t) => t,
        Err(e) => {
            out.push(Violation {
                rule: "E0007",
                slug: "bench-discipline",
                file: "rust/Cargo.toml".to_string(),
                line: 1,
                msg: format!("cannot read manifest: {e}"),
            });
            return;
        }
    };
    for (name, line) in bench_entries(&manifest) {
        match fs::read_to_string(root.join("rust/benches").join(format!("{name}.rs"))) {
            Ok(src) => check_bench_source(&name, line, &src, out),
            Err(e) => out.push(Violation {
                rule: "E0007",
                slug: "bench-discipline",
                file: "rust/Cargo.toml".to_string(),
                line,
                msg: format!("bench `{name}` has no source at rust/benches/{name}.rs: {e}"),
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            walk_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn find_root() -> Option<PathBuf> {
    if let Some(arg) = std::env::args().nth(1) {
        let p = PathBuf::from(arg);
        return if p.join("rust/src").is_dir() { Some(p) } else { None };
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() {
    let Some(root) = find_root() else {
        eprintln!("repolint: cannot locate the repo root (looked for rust/src upward from cwd)");
        std::process::exit(2);
    };
    let mut files = Vec::new();
    for dir in ["rust/src", "rust/tests", "rust/benches"] {
        walk_rs(&root.join(dir), &mut files);
    }
    files.sort();

    let readme = fs::read_to_string(root.join("README.md")).unwrap_or_default();
    let mut violations = Vec::new();
    let mut consts = HashMap::new();
    let mut scans: Vec<(String, Scan)> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(text) = fs::read_to_string(path) else {
            eprintln!("repolint: skipping unreadable {rel}");
            continue;
        };
        let scan = scan_source(&text);
        collect_consts(&scan, &mut consts);
        scans.push((rel, scan));
    }
    let mut regs = Vec::new();
    let mut fault_sites = Vec::new();
    for (rel, scan) in &scans {
        check_unsafe(scan, rel, &mut violations);
        check_panics(scan, rel, &mut violations);
        check_hot_allocs(scan, rel, &mut violations);
        if rel == "rust/src/lib.rs" {
            check_module_map(scan, rel, &mut violations);
        }
        // the registry implementation itself forwards `name` parameters;
        // every real registration goes through its public methods
        if rel.starts_with("rust/src/") && rel != "rust/src/obs/metrics.rs" {
            collect_metric_calls(scan, rel, &mut regs);
        }
        // production sites only: tests and benches may probe ad-hoc names
        // (e.g. the disabled-plane microcheck's `bench.noop`)
        if rel.starts_with("rust/src/") {
            collect_fault_sites(scan, rel, &mut fault_sites);
        }
    }
    check_metrics(&regs, &consts, &readme, &mut violations);
    check_fault_sites(&fault_sites, &readme, &mut violations);
    check_benches(&root, &mut violations);

    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("repolint: ok — {} files, 8 rules, 0 violations", scans.len());
    } else {
        eprintln!("repolint: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(text: &str) -> Scan {
        scan_source(text)
    }

    // -- scanner ----------------------------------------------------------

    #[test]
    fn strips_comments_and_strings_preserving_columns() {
        let s = scan("let x = \"unsafe .unwrap()\"; // panic! here\n");
        assert_eq!(s.code[0].len(), s.raw[0].chars().count());
        assert!(!s.code[0].contains("unsafe"));
        assert!(!s.code[0].contains("panic!"));
        assert!(s.comments[0].contains("panic! here"));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let s = scan("let a = r#\"vec![oops]\"#; let b = '\"'; let c: &'static str = \"x\";\n");
        assert!(!s.code[0].contains("vec!["));
        assert!(s.code[0].contains("&'static str"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let s = scan("/* outer /* unsafe */ still comment */ let x = 1;\nlet y = 2;\n");
        assert!(!s.code[0].contains("unsafe"));
        assert!(s.code[0].contains("let x = 1;"));
        assert!(s.code[1].contains("let y = 2;"));
    }

    #[test]
    fn cfg_test_regions_marked() {
        let text = "fn live() { a.unwrap(); }\n\
                    #[cfg(test)]\n\
                    mod tests {\n    fn t() { b.unwrap(); }\n}\n\
                    fn live2() {}\n";
        let s = scan(text);
        assert!(!s.in_test[0]);
        assert!(s.in_test[2] && s.in_test[3] && s.in_test[4]);
        assert!(!s.in_test[5]);
    }

    // -- E0001 / E0002 ----------------------------------------------------

    #[test]
    fn safety_comment_accepted_and_chained() {
        let text = "// SAFETY: disjoint rows, workers joined before return.\n\
                    unsafe impl<T> Sync for S<T> {}\n\
                    unsafe impl<T> Send for S<T> {}\n";
        let mut v = Vec::new();
        check_unsafe(&scan(text), "rust/src/util/pool.rs", &mut v);
        assert!(v.is_empty(), "{:?}", v.iter().map(|x| x.to_string()).collect::<Vec<_>>());
    }

    #[test]
    fn missing_safety_comment_flagged() {
        let mut v = Vec::new();
        check_unsafe(&scan("let p = unsafe { &mut *q };\n"), "rust/src/util/pool.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "E0001");
    }

    #[test]
    fn unsafe_outside_allowlist_flagged_and_escapable() {
        let bad = "// SAFETY: fine.\nunsafe { x() };\n";
        let mut v = Vec::new();
        check_unsafe(&scan(bad), "rust/src/model/linear.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "E0002");
        let ok = "// SAFETY: fine.\n// UNSAFE-OK: test-only exercise of the pool contract.\nunsafe { x() };\n";
        v.clear();
        check_unsafe(&scan(ok), "rust/src/model/linear.rs", &mut v);
        assert!(v.is_empty());
    }

    // -- E0003 ------------------------------------------------------------

    #[test]
    fn serving_panic_flagged_not_in_tests_or_elsewhere() {
        let text = "fn f() { x.unwrap(); }\n\
                    #[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n";
        let mut v = Vec::new();
        check_panics(&scan(text), "rust/src/coordinator/server.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("E0003", 1));
        v.clear();
        check_panics(&scan(text), "rust/src/quant/lords.rs", &mut v);
        assert!(v.is_empty(), "non-serving files are out of scope");
    }

    #[test]
    fn panic_ok_annotation_accepted() {
        let text = "// PANIC-OK: sealed blocks always have storage (seal_tile invariant).\n\
                    let s = b.storage.expect(\"sealed\");\n\
                    let t = c.unwrap_or_default();\n";
        let mut v = Vec::new();
        check_panics(&scan(text), "rust/src/kvquant/pool.rs", &mut v);
        assert!(v.is_empty(), "unwrap_or_default must not match `.unwrap()`");
    }

    // -- E0004 ------------------------------------------------------------

    #[test]
    fn hot_fn_alloc_flagged_and_escapable() {
        let text = "pub fn rmsnorm_fwd_into(x: &M, y: &mut M) {\n\
                    \x20   let tmp = x.data.to_vec();\n\
                    }\n";
        let mut v = Vec::new();
        check_hot_allocs(&scan(text), "rust/src/model/norm.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "E0004");
        let ok = "pub fn rmsnorm_fwd_into(x: &M, y: &mut M) {\n\
                  \x20   // ALLOC-OK: one-time warm-up, amortised across calls.\n\
                  \x20   let tmp = x.data.to_vec();\n\
                  }\n";
        v.clear();
        check_hot_allocs(&scan(ok), "rust/src/model/norm.rs", &mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn renamed_hot_fn_is_a_violation() {
        let mut v = Vec::new();
        check_hot_allocs(&scan("pub fn other() {}\n"), "rust/src/model/norm.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("not found"));
    }

    #[test]
    fn fn_body_extraction_brace_matches() {
        let text = "pub fn forward_into(a: usize) {\n    if a > 0 { b(); }\n}\n\
                    pub fn unrelated() { let v = vec![0; 4]; }\n";
        let bodies = fn_bodies(&scan(text), "forward_into");
        assert_eq!(bodies, vec![(0, 2)]);
    }

    // -- E0005 ------------------------------------------------------------

    #[test]
    fn bare_metric_without_help_or_readme_flagged() {
        let text = "fn obs(reg: &Registry) {\n\
                    \x20   reg.counter(\"lords_x_total\", &[]);\n\
                    }\n";
        let mut regs = Vec::new();
        collect_metric_calls(&scan(text), "rust/src/coordinator/server.rs", &mut regs);
        let mut v = Vec::new();
        check_metrics(&regs, &HashMap::new(), "no table here", &mut v);
        assert_eq!(v.len(), 2, "missing help + missing README row");
        assert!(v.iter().all(|x| x.rule == "E0005"));
    }

    #[test]
    fn const_resolution_and_set_help_satisfy_the_rule() {
        let text = "pub const X_FAMILY: &str = \"lords_x_total\";\n\
                    fn obs(reg: &Registry) {\n\
                    \x20   reg.set_help(X_FAMILY, \"Help.\");\n\
                    \x20   reg.counter(quality::X_FAMILY, &[(\"k\", \"v\")]);\n\
                    }\n";
        let s = scan(text);
        let mut consts = HashMap::new();
        collect_consts(&s, &mut consts);
        assert_eq!(consts.get("X_FAMILY").map(String::as_str), Some("lords_x_total"));
        let mut regs = Vec::new();
        collect_metric_calls(&s, "rust/src/obs/quality.rs", &mut regs);
        let mut v = Vec::new();
        check_metrics(&regs, &consts, "| `lords_x_total` | counter | ... |", &mut v);
        assert!(v.is_empty(), "{:?}", v.iter().map(|x| x.to_string()).collect::<Vec<_>>());
    }

    #[test]
    fn opaque_metric_name_needs_annotation() {
        let text = "fn obs(reg: &Registry, fam: &str) {\n\
                    \x20   // METRIC-OK: family picked by callers; both spellings are consts.\n\
                    \x20   reg.gauge(&fam[..], &[]);\n\
                    }\n";
        let mut regs = Vec::new();
        collect_metric_calls(&scan(text), "rust/src/obs/quality.rs", &mut regs);
        let mut v = Vec::new();
        check_metrics(&regs, &HashMap::new(), "", &mut v);
        assert!(v.is_empty());
    }

    // -- E0006 ------------------------------------------------------------

    #[test]
    fn module_map_row_required() {
        let text = "//! | [`util`] | helpers |\npub mod util;\npub mod stray;\n";
        let mut v = Vec::new();
        check_module_map(&scan(text), "rust/src/lib.rs", &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("`stray`"));
    }

    // -- E0008 ------------------------------------------------------------

    #[test]
    fn undocumented_fault_site_flagged_documented_one_passes() {
        let text = "fn seal(&mut self) -> anyhow::Result<()> {\n\
                    \x20   if let Some(k) = crate::fault::point!(\"kv.seal\") {\n\
                    \x20       crate::fault::apply_fallible(\"kv.seal\", k)?;\n\
                    \x20   }\n\
                    }\n";
        let mut sites = Vec::new();
        collect_fault_sites(&scan(text), "rust/src/kvquant/pool.rs", &mut sites);
        assert_eq!(sites.len(), 1, "only the macro call is a site, not apply_fallible");
        let mut v = Vec::new();
        check_fault_sites(&sites, "no table here", &mut v);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "E0008");
        assert!(v[0].msg.contains("`kv.seal`"));
        v.clear();
        check_fault_sites(&sites, "| `kv.seal` | block seal | err, latency |", &mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn non_literal_fault_site_needs_annotation() {
        let bad = "fn f(site: &str) { let _ = crate::fault::point!(site); }\n";
        let mut sites = Vec::new();
        collect_fault_sites(&scan(bad), "rust/src/fault/mod.rs", &mut sites);
        let mut v = Vec::new();
        check_fault_sites(&sites, "", &mut v);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("not a string literal"));
        let ok = "fn f(site: &str) {\n\
                  \x20   // FAULT-OK: forwarding helper; callers pass documented literals.\n\
                  \x20   let _ = crate::fault::point!(site);\n\
                  }\n";
        sites.clear();
        collect_fault_sites(&scan(ok), "rust/src/fault/mod.rs", &mut sites);
        v.clear();
        check_fault_sites(&sites, "", &mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn fault_sites_in_tests_are_exempt() {
        let text = "#[cfg(test)]\nmod tests {\n\
                    \x20   fn t() { let _ = crate::fault::point!(\"test.only\"); }\n}\n";
        let mut sites = Vec::new();
        collect_fault_sites(&scan(text), "rust/src/fault/mod.rs", &mut sites);
        assert!(sites.is_empty());
    }

    // -- E0007 ------------------------------------------------------------

    #[test]
    fn bench_entries_parsed_from_manifest() {
        let manifest = "[package]\nname = \"lords\"\n\n[[bench]]\nname = \"fig2\"\nharness = false\n\n[[bench]]\nname = \"t1\"\n";
        let entries = bench_entries(manifest);
        assert_eq!(entries, vec![("fig2".to_string(), 4), ("t1".to_string(), 8)]);
    }

    #[test]
    fn bench_rules_flag_missing_smoke_and_baseline() {
        let mut v = Vec::new();
        check_bench_source("t1", 4, "fn main() { run_forever(); }", &mut v);
        assert_eq!(v.len(), 2);
        v.clear();
        check_bench_source(
            "t1",
            4,
            "use lords::report::testbed::full_mode;\nfn main() { write(\"BENCH_t1.json\"); }",
            &mut v,
        );
        assert!(v.is_empty());
        v.clear();
        check_bench_source("t1", 4, "// BENCH-OK: profiling-only driver.\nfn main() {}", &mut v);
        assert!(v.is_empty());
    }
}
